//! SwapNet CLI — the L3 coordinator entrypoint.
//!
//! Subcommands map to the paper's experiments:
//!   scenario   run a multi-DNN scenario under a method (Figs 11-13)
//!   ablation   intermediate system versions (Fig 15)
//!   profile    delay-coefficient regression (Fig 9)
//!   partition  build + prune a lookup table (Table 3)
//!   adapt      dynamic-budget adaptation trace (Fig 18)
//!   serve      real PJRT serving of an artifact model (e2e driver)
//!   overhead   memory + power overhead (Fig 19)
//!   table1     non-DNN memory trace (Table 1)
//!   table2     model info table (Table 2)
//!
//! (clap is not in the offline crate universe; a small hand-rolled parser
//! covers the `--key value` grammar.)

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use swapnet::config::{DeviceProfile, MB};
use swapnet::coordinator::{run_scenario, run_snet_model, SnetConfig};
use swapnet::delay::{profiler, DelayModel};
use swapnet::model::{artifacts, families};
use swapnet::scheduler::{self, adapt::AdaptiveScheduler, partition};
use swapnet::util::table;
use swapnet::workload;

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            out.insert(key.to_string(), val);
        }
        i += 1;
    }
    out
}

fn device(flags: &HashMap<String, String>) -> DeviceProfile {
    let name = flags.get("device").map(String::as_str).unwrap_or("nx");
    DeviceProfile::by_name(name).unwrap_or_else(|| {
        eprintln!("unknown device {name}, using jetson-nx");
        DeviceProfile::jetson_nx()
    })
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(String::as_str).unwrap_or("help");
    let flags = parse_flags(&argv[argv.len().min(1)..]);

    match cmd {
        "scenario" => cmd_scenario(&flags),
        "ablation" => cmd_ablation(&flags),
        "profile" => cmd_profile(&flags),
        "partition" => cmd_partition(&flags),
        "adapt" => cmd_adapt(&flags),
        "serve" => cmd_serve(&flags),
        "overhead" => cmd_overhead(&flags),
        "table1" => cmd_table1(),
        "table2" => cmd_table2(&flags),
        _ => {
            println!(
                "swapnet — DNN inference beyond the memory budget (TMC'24 reproduction)\n\
                 usage: swapnet <scenario|ablation|profile|partition|adapt|serve|overhead|table1|table2> [--flags]\n\
                 see README.md for examples"
            );
            Ok(())
        }
    }
}

fn cmd_scenario(flags: &HashMap<String, String>) -> Result<()> {
    let name = flags.get("name").map(String::as_str).unwrap_or("self-driving");
    let sc = workload::by_name(name).ok_or_else(|| anyhow!("unknown scenario {name}"))?;
    let prof = device(flags);
    let methods: Vec<&str> = flags
        .get("method")
        .map(|m| vec![m.as_str()])
        .unwrap_or_else(|| vec!["DInf", "DCha", "TPrg", "SNet"]);
    println!(
        "scenario {} on {}: fleet {} over budget {} (pressure {:.2}x)",
        sc.name,
        prof.name,
        table::human_bytes(sc.fleet_bytes()),
        table::human_bytes(sc.dnn_budget),
        sc.pressure()
    );
    let mut rows = Vec::new();
    for m in methods {
        for r in run_scenario(&sc, m, &prof, &SnetConfig::default()).map_err(|e| anyhow!(e))? {
            rows.push(r.row());
        }
    }
    println!("{}", table::render(&["model", "method", "peak mem", "latency", "accuracy"], &rows));
    Ok(())
}

fn cmd_ablation(flags: &HashMap<String, String>) -> Result<()> {
    let prof = device(flags);
    let sc = workload::self_driving();
    let variants: [(&str, SnetConfig); 4] = [
        ("SNet (full)", SnetConfig::default()),
        ("w/o-uni-add", SnetConfig { unified_addressing: false, ..Default::default() }),
        ("w/o-mod-ske", SnetConfig { skeleton_assembly: false, ..Default::default() }),
        ("w/o-pat-sch", SnetConfig { partition_scheduling: false, ..Default::default() }),
    ];
    let mut rows = Vec::new();
    let budgets = swapnet::coordinator::scenario_budgets(&sc, &prof);
    for (label, cfg) in variants {
        for (model, &budget) in sc.models.iter().zip(&budgets) {
            let run = run_snet_model(model, budget, &prof, &cfg).map_err(|e| anyhow!(e))?;
            rows.push(vec![
                label.to_string(),
                model.name.clone(),
                table::human_bytes(run.peak_bytes),
                table::human_secs(run.latency_s),
            ]);
        }
    }
    println!("{}", table::render(&["variant", "model", "peak mem", "latency"], &rows));
    Ok(())
}

fn cmd_profile(flags: &HashMap<String, String>) -> Result<()> {
    let prof = device(flags);
    let sweep = profiler::measure_sweep(&prof, 300, 0.03, 42);
    let fit = profiler::fit(&sweep);
    println!("device {}: fitted coefficients (Fig 9)", prof.name);
    println!(
        "  alpha = {:.3e} s/B (true {:.3e})  r2_in={:.4}",
        fit.alpha_s_per_byte, prof.alpha_s_per_byte, fit.r2_in
    );
    println!(
        "  beta  = {:.1} us/ref (true {:.1})",
        fit.beta_s_per_depth * 1e6,
        prof.beta_s_per_depth * 1e6
    );
    println!(
        "  gamma = {:.3e} s/FLOP (true {:.3e})  r2_ex={:.4}",
        fit.gamma_s_per_flop, prof.gamma_cpu_s_per_flop, fit.r2_ex
    );
    println!(
        "  eta   = {:.1} us/ref (true {:.1})  gc={:.1} ms  r2_out={:.4}",
        fit.eta_s_per_depth * 1e6,
        prof.eta_s_per_depth * 1e6,
        fit.gc_s * 1e3,
        fit.r2_out
    );
    Ok(())
}

fn cmd_partition(flags: &HashMap<String, String>) -> Result<()> {
    let model_name = flags.get("model").map(String::as_str).unwrap_or("resnet101");
    let budget_mb: u64 = flags.get("budget-mb").and_then(|s| s.parse().ok()).unwrap_or(102);
    let n: usize = flags.get("blocks").and_then(|s| s.parse().ok()).unwrap_or(3);
    let model = families::by_name(model_name).ok_or_else(|| anyhow!("unknown model"))?;
    let prof = device(flags);
    let dm = DelayModel::from_profile(&prof);
    let t = partition::build_lookup_table(&model, n, &dm);
    println!(
        "{} into {} blocks: {} candidate partitions ({} table)",
        model.name,
        n,
        t.rows.len(),
        table::human_bytes(t.approx_bytes())
    );
    let usable = (budget_mb as f64 * MB as f64 * 0.964) as u64;
    let mut rows = Vec::new();
    for r in t.rows.iter().take(5) {
        rows.push(row_of(r, usable));
    }
    rows.push(vec!["...".into(), "...".into(), "...".into()]);
    if let Some(best) = t.best_within(usable) {
        rows.push(row_of(best, usable));
        println!(
            "{}",
            table::render(&["partition points", "max memory", "predicted latency"], &rows)
        );
        println!(
            "best within {budget_mb} MB: {:?} -> {}",
            best.points,
            table::human_secs(best.predicted_latency_s)
        );
    } else {
        println!(
            "{}",
            table::render(&["partition points", "max memory", "predicted latency"], &rows)
        );
        println!("no feasible {n}-block partition within {budget_mb} MB");
    }
    Ok(())
}

fn row_of(r: &partition::Row, usable: u64) -> Vec<String> {
    vec![
        format!("{:?}", r.points),
        if r.max_mem_bytes <= usable {
            table::human_bytes(r.max_mem_bytes)
        } else {
            "exceed".into()
        },
        if r.max_mem_bytes <= usable {
            table::human_secs(r.predicted_latency_s)
        } else {
            "null".into()
        },
    ]
}

fn cmd_adapt(flags: &HashMap<String, String>) -> Result<()> {
    let prof = device(flags);
    let mut ad = AdaptiveScheduler::register(families::resnet101(), &prof, 6);
    println!("Fig 18: runtime adaptation of ResNet-101 partitioning");
    for (t, budget) in workload::fig18_budget_trace() {
        let s = ad.adapt(budget).map_err(|e| anyhow!(e))?;
        let (_, _, dt) = *ad.history.last().unwrap();
        println!(
            "  t={t:>5.1}s budget={:>8} -> {} blocks at {:?}, predicted {} (adaptation {:.1} ms)",
            table::human_bytes(budget),
            s.n_blocks,
            s.points,
            table::human_secs(s.predicted_latency_s),
            dt * 1e3
        );
    }
    Ok(())
}

fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    let dir = artifacts::artifacts_dir();
    let model_name = flags.get("model").map(String::as_str).unwrap_or("tiny_cnn");
    let model = artifacts::ArtifactModel::load(&dir.join(model_name))?;
    let rt = swapnet::runtime::Runtime::cpu()?;
    let cfg = swapnet::server::ServeConfig {
        rate_hz: flags.get("rate").and_then(|s| s.parse().ok()).unwrap_or(100.0),
        requests: flags.get("requests").and_then(|s| s.parse().ok()).unwrap_or(200),
        points: flags
            .get("points")
            .map(|s| s.split(',').filter_map(|x| x.parse().ok()).collect())
            .unwrap_or_default(),
        ..Default::default()
    };
    let rep = swapnet::server::serve(&rt, &model, &cfg)?;
    println!(
        "served {} requests in {:.2}s wall: {:.1} req/s, batch avg {:.2}, latency p50 {} p95 {} p99 {}",
        rep.served,
        rep.wall_s,
        rep.throughput_rps,
        rep.mean_batch,
        table::human_secs(rep.latency.p(50.0)),
        table::human_secs(rep.latency.p(95.0)),
        table::human_secs(rep.latency.p(99.0)),
    );
    Ok(())
}

fn cmd_overhead(flags: &HashMap<String, String>) -> Result<()> {
    let prof = device(flags);
    println!("Fig 19a: SwapNet memory overhead per model");
    let mut rows = Vec::new();
    for m in workload::self_driving().models {
        let budget = scheduler::minimal_budget(&m).max(m.size_bytes() / 3);
        let sched = scheduler::schedule_model(&m, budget, &DelayModel::from_profile(&prof), &prof)
            .map_err(|e| anyhow!(e))?;
        let blocks = m.create_blocks(&sched.points).map_err(|e| anyhow!(e))?;
        let sk: u64 = blocks
            .iter()
            .map(|b| {
                swapnet::assembly::AssemblyController::skeleton_bytes(
                    &swapnet::assembly::synthetic_skeleton(b),
                )
            })
            .sum();
        let act = swapnet::baselines::activation_bytes(&m.family);
        let tbl = 600_000u64;
        rows.push(vec![
            m.name.clone(),
            table::human_bytes(sk),
            table::human_bytes(act),
            table::human_bytes(tbl),
            format!("{:.1}%", 100.0 * (sk + act + tbl) as f64 / m.size_bytes() as f64),
        ]);
    }
    println!(
        "{}",
        table::render(&["model", "skeleton", "activations", "tables", "of model"], &rows)
    );

    println!("\nFig 19b: power (W) — SNet vs DInf on {}", prof.name);
    let m = families::resnet101();
    let run = run_snet_model(&m, 120 * MB, &prof, &SnetConfig::default()).map_err(|e| anyhow!(e))?;
    let tr = swapnet::power::trace_for_timeline(&run.timeline, m.processor, &prof, 0.005, 0.2);
    let dinf_tl = swapnet::pipeline::timeline(&[swapnet::pipeline::BlockTimes {
        t_in: 0.0,
        t_ex: DelayModel::from_profile(&prof).t_ex(&m.single_block(), m.processor),
        t_out: 0.0,
    }]);
    let tr_dinf = swapnet::power::trace_for_timeline(&dinf_tl, m.processor, &prof, 0.005, 0.2);
    println!(
        "  idle {:.2} W | SNet active {:.2} W (peak {:.2}) | DInf active {:.2} W | swap overhead {:+.2} W",
        prof.power.idle_w,
        tr.avg_active_w(&prof),
        tr.peak_w(),
        tr_dinf.avg_active_w(&prof),
        tr.avg_active_w(&prof) - tr_dinf.avg_active_w(&prof)
    );
    Ok(())
}

fn cmd_table1() -> Result<()> {
    let tasks = workload::table1_non_dnn();
    let total: u64 = 8192 * MB;
    let used: u64 = tasks.iter().map(|t| t.mem_bytes).sum();
    let mut rows: Vec<Vec<String>> = tasks
        .iter()
        .map(|t| {
            vec![
                t.name.clone(),
                table::human_bytes(t.mem_bytes),
                format!("{:.1}%", 100.0 * t.mem_bytes as f64 / total as f64),
            ]
        })
        .collect();
    rows.push(vec![
        "Remaining Memory".into(),
        table::human_bytes(total - used),
        format!("{:.1}%", 100.0 * (total - used) as f64 / total as f64),
    ]);
    println!("{}", table::render(&["Tasks", "Memory Usage", "Percentage"], &rows));
    Ok(())
}

fn cmd_table2(flags: &HashMap<String, String>) -> Result<()> {
    let name = flags.get("model").map(String::as_str).unwrap_or("resnet101");
    let m = families::by_name(name).ok_or_else(|| anyhow!("unknown model"))?;
    let mut rows = Vec::new();
    for (i, l) in m.layers.iter().enumerate() {
        if i < 6 || i + 2 >= m.layers.len() {
            rows.push(vec![
                format!("Layer{} ({})", i + 1, l.name),
                table::human_bytes(l.size_bytes),
                l.depth.to_string(),
                format!("{:.1} M", l.flops as f64 / 1e6),
            ]);
        } else if i == 6 {
            rows.push(vec!["...".into(), "...".into(), "...".into(), "...".into()]);
        }
    }
    println!("{}", table::render(&["Layer", "Size", "Depth", "FLOPs"], &rows));
    println!(
        "total: {} over {} layers, {:.1} GFLOPs",
        table::human_bytes(m.size_bytes()),
        m.layers.len(),
        m.total_flops() as f64 / 1e9
    );
    Ok(())
}
