//! Exact interval-DP partition search (paper Eq. 2-4 as optimization,
//! not enumeration).
//!
//! The historical table builder enumerated all C(cuts, n-1) partitions
//! for n <= 3 and fell back to a lossy beam search beyond. This module
//! replaces both with one exact dynamic program over prefix states:
//! blocks are intervals between legal cut points, and the pipeline
//! timeline of `pipeline::timeline_spec` is advanced incrementally one
//! block at a time. Everything the timeline needs to continue from a
//! prefix is a small state vector — per-channel free times, the last
//! exec end, the residency gate's folded prefix max, the out-done times
//! of the last m blocks, the sizes of the last m-1 blocks (for the
//! m-window memory peak), and the running peak — and every component is
//! *monotone*: a prefix state that is <= another componentwise can only
//! produce <= latencies and peaks downstream. Dominance pruning over
//! that partial order is therefore exact, and the incremental timeline
//! performs bit-for-bit the same float operations as evaluating the
//! full partition, so the DP's best row is identical to exhaustive
//! enumeration's (property-tested in `tests/prop.rs`).
//!
//! Complexity: O(cuts^2 * n) cell transitions, times the (small, capped)
//! per-cell dominance frontier — versus C(cuts, n-1) full-partition
//! evaluations for enumeration. At ResNet-101 scale and n = 8 that is
//! orders of magnitude fewer block evaluations (`benches/micro_planner`
//! gates the >= 10x claim in CI).

use crate::model::{BlockInfo, ModelInfo};
use crate::pipeline::{PipelineSpec, SwapVariant, VariantPolicy};
use crate::scheduler::partition::Row;

use super::cost::CostProvider;

/// Safety valve on the per-cell dominance frontier. It must exceed the
/// largest legal-cut count of any model (a stage-2 cell holds at most
/// one state per predecessor cut), so the bound never binds for n <= 3
/// and the exactness proof there is unconditional; beyond, it caps
/// worst-case state growth while keeping the search far above beam
/// quality.
const FRONTIER_CAP: usize = 128;

/// Outcome of one DP run: the (memory, latency) Pareto frontier of
/// n-block partitions, plus search-effort counters.
#[derive(Debug, Clone)]
pub struct DpResult {
    /// Frontier rows sorted by ascending memory with strictly
    /// descending latency. `best_within(usable)` over these equals the
    /// optimum over ALL n-block partitions whenever no cell frontier
    /// exceeded the `FRONTIER_CAP` safety valve — unconditionally true
    /// for n <= 3;
    /// past the cap both the fast and the low-memory ends of each cell
    /// are preserved, so quality degrades gracefully (and never below
    /// the beam search this DP replaced — property-tested).
    pub rows: Vec<Row>,
    /// Block-interval evaluations performed (the DP's analogue of one
    /// `evaluate_spec` call per enumerated partition).
    pub evals: u64,
    /// True when any cell frontier hit the safety cap and states were
    /// heuristically trimmed — the optimality guarantee degraded to
    /// best-effort for this run. Surfaces in release builds (the
    /// cut-count debug_assert compiles out) via
    /// `PlanStats::capped_frontiers`.
    pub capped: bool,
}

impl DpResult {
    /// Latency-minimal row fitting `usable` bytes. The frontier is
    /// sorted by memory with strictly decreasing latency, so the last
    /// feasible row is the optimum.
    pub fn best_within(&self, usable: u64) -> Option<&Row> {
        self.rows.iter().rev().find(|r| r.max_mem_bytes <= usable)
    }
}

/// One prefix state of the incremental pipeline timeline. All fields
/// except `points` are monotone cost components (see module docs).
#[derive(Debug, Clone)]
struct State {
    /// Per-channel next-free times, sorted ascending (the timeline picks
    /// the earliest-free channel; only the multiset matters).
    chan_free: Vec<f64>,
    /// Exec end of the last placed block (= prefix latency).
    exec_end: f64,
    /// Folded prefix max of swap-out completions older than the last m.
    gate_max: f64,
    /// Swap-out completion times of the last min(k, m) blocks, oldest
    /// first (the ones future residency gates will fold).
    out_tail: Vec<f64>,
    /// Working-set bytes of the last min(k, m-1) blocks, oldest first
    /// (the open part of the next m-window). Equal to the block sizes
    /// for Plain/Compressed; two tiles for Tiled.
    tail_sizes: Vec<u64>,
    /// Running max over completed m-windows.
    peak: u64,
    /// Sum of all placed blocks' working sets (the n < m whole-window
    /// peak, where no m-window ever completes).
    ws_sum: u64,
    /// Cut points chosen so far.
    points: Vec<usize>,
    /// Swap variant chosen for each placed block.
    variants: Vec<SwapVariant>,
}

impl State {
    fn initial(channels: usize) -> State {
        State {
            chan_free: vec![0.0; channels],
            exec_end: 0.0,
            gate_max: 0.0,
            out_tail: Vec::new(),
            tail_sizes: Vec::new(),
            peak: 0,
            ws_sum: 0,
            points: Vec::new(),
            variants: Vec::new(),
        }
    }
}

/// `a` dominates `b`: every cost component of `a` is <= `b`'s, so every
/// continuation of `a` costs no more than the same continuation of `b`.
/// `ws_sum` is deliberately NOT compared: it only reaches a row's memory
/// column when n < m, and in that regime the tail never trims (at most
/// n - 1 < m - 1 prefix blocks), so `tail_sizes` already carries every
/// working set and elementwise tail dominance implies ws_sum dominance.
fn dominates(a: &State, b: &State) -> bool {
    a.exec_end <= b.exec_end
        && a.gate_max <= b.gate_max
        && a.peak <= b.peak
        && a.chan_free.iter().zip(&b.chan_free).all(|(x, y)| x <= y)
        && a.out_tail.iter().zip(&b.out_tail).all(|(x, y)| x <= y)
        && a.tail_sizes.iter().zip(&b.tail_sizes).all(|(x, y)| x <= y)
}

/// Insert `cand` into a cell's dominance frontier (drop it if covered,
/// evict anything it covers, cap the frontier size). When the cap
/// binds, BOTH ends of the frontier survive — the lowest-latency
/// states and, from the remainder, the lowest-memory states — so tight
/// budgets keep feasible prefixes even past the cap.
fn insert(frontier: &mut Vec<State>, cand: State, capped: &mut bool) {
    if frontier.iter().any(|s| dominates(s, &cand)) {
        return;
    }
    frontier.retain(|s| !dominates(&cand, s));
    frontier.push(cand);
    if frontier.len() > FRONTIER_CAP {
        *capped = true;
        frontier.sort_by(|a, b| {
            a.exec_end.total_cmp(&b.exec_end).then(a.peak.cmp(&b.peak))
        });
        let mut rest = frontier.split_off(FRONTIER_CAP / 2);
        rest.sort_by(|a, b| {
            a.peak.cmp(&b.peak).then(a.exec_end.total_cmp(&b.exec_end))
        });
        rest.truncate(FRONTIER_CAP - FRONTIER_CAP / 2);
        frontier.append(&mut rest);
    }
}

/// Per-layer prefix sums for O(1) block metrics.
struct Prefix {
    size: Vec<u64>,
    depth: Vec<u64>,
    flops: Vec<u64>,
}

impl Prefix {
    fn of(model: &ModelInfo) -> Prefix {
        let n = model.layers.len();
        let mut size = Vec::with_capacity(n + 1);
        let mut depth = Vec::with_capacity(n + 1);
        let mut flops = Vec::with_capacity(n + 1);
        size.push(0);
        depth.push(0);
        flops.push(0);
        for l in &model.layers {
            size.push(size.last().copied().unwrap_or(0) + l.size_bytes);
            depth.push(depth.last().copied().unwrap_or(0) + l.depth as u64);
            flops.push(flops.last().copied().unwrap_or(0) + l.flops);
        }
        Prefix { size, depth, flops }
    }

    fn block(&self, index: usize, lo: usize, hi: usize) -> BlockInfo {
        BlockInfo {
            index,
            layer_lo: lo,
            layer_hi: hi,
            size_bytes: self.size[hi] - self.size[lo],
            depth: (self.depth[hi] - self.depth[lo]) as u32,
            flops: self.flops[hi] - self.flops[lo],
        }
    }
}

/// Advance the incremental timeline by the block spanning layers
/// (lo, hi], swapped under `variant`. Replicates
/// `pipeline::timeline_spec`'s per-block float operations exactly for
/// `SwapVariant::Plain` (see the parity property tests); other variants
/// substitute the variant's delay triple and charge its working set in
/// place of the block size.
#[allow(clippy::too_many_arguments)]
fn extend(
    st: &State,
    lo: usize,
    hi: usize,
    index: usize,
    model: &ModelInfo,
    prefix: &Prefix,
    costs: &dyn CostProvider,
    m: usize,
    variant: SwapVariant,
    is_final: bool,
) -> State {
    let b = prefix.block(index, lo, hi);
    let t = costs.variant_times(&b, model.processor, variant);
    let ws = variant.working_set(b.size_bytes);
    let mut next = st.clone();
    // Residency gate: fold the (k-m)-th block's swap-out completion once
    // the tail holds m entries — identical to the i >= m branch of
    // `timeline_spec`.
    let mem_free = if next.out_tail.len() == m {
        let popped = next.out_tail.remove(0);
        next.gate_max = next.gate_max.max(popped);
        next.gate_max
    } else {
        0.0
    };
    // Earliest-free channel (sorted, so index 0).
    let swap_start = next.chan_free[0].max(mem_free);
    let swap_end = swap_start + t.t_in;
    next.chan_free[0] = swap_end;
    next.chan_free.sort_by(f64::total_cmp);
    let exec_start = next.exec_end.max(swap_end);
    next.exec_end = exec_start + t.t_ex;
    next.out_tail.push(next.exec_end + t.t_out);
    // m-window memory peak: a window completes once m-1 older working
    // sets are open in the tail.
    if next.tail_sizes.len() == m - 1 {
        let window: u64 = next.tail_sizes.iter().sum::<u64>() + ws;
        next.peak = next.peak.max(window);
    }
    next.tail_sizes.push(ws);
    if next.tail_sizes.len() > m.saturating_sub(1) {
        next.tail_sizes.remove(0);
    }
    next.ws_sum += ws;
    next.variants.push(variant);
    if !is_final {
        next.points.push(hi);
    }
    next
}

/// Exact DP over legal cut points: the (memory, latency) Pareto
/// frontier of all n-block partitions of `model` under `spec`, with the
/// per-block times supplied by `costs`. Plain-only — the historical
/// search space, bit-identical to the pre-variant planner.
pub fn frontier(
    model: &ModelInfo,
    n: usize,
    costs: &dyn CostProvider,
    spec: &PipelineSpec,
) -> DpResult {
    frontier_with(model, n, costs, spec, VariantPolicy::default())
}

/// The variant-aware DP (DESIGN.md §13): identical interval search, but
/// each block placement branches over `policy.candidates()` — the same
/// dominance pruning then keeps compressed prefixes when the codec wins
/// on latency and tiled prefixes as the low-memory end of each cell.
/// Under the default policy the candidate set is `{Plain}` and every
/// float operation matches [`frontier`] exactly.
pub fn frontier_with(
    model: &ModelInfo,
    n: usize,
    costs: &dyn CostProvider,
    spec: &PipelineSpec,
    policy: VariantPolicy,
) -> DpResult {
    let cands = policy.candidates();
    let m = spec.residency_m.max(1);
    let channels = spec.swap_channels.max(1);
    let cuts = model.legal_cut_points();
    let l = model.layers.len();
    let k_cuts = n.saturating_sub(1);
    let mut evals = 0u64;
    let mut capped = false;
    if n == 0 || cuts.len() < k_cuts || l == 0 {
        return DpResult { rows: Vec::new(), evals, capped };
    }
    // Exactness precondition (see FRONTIER_CAP): a stage-2 cell holds
    // one state per predecessor cut, so the n <= 3 bitwise-exactness
    // contract needs the cap to exceed the legal-cut count. Every
    // in-tree family sits far below it; trip loudly in debug builds if
    // a future chain outgrows the valve instead of silently degrading.
    debug_assert!(
        cuts.len() < FRONTIER_CAP,
        "{}: {} legal cuts >= FRONTIER_CAP {} — raise the cap to keep the DP exact",
        model.name,
        cuts.len(),
        FRONTIER_CAP
    );
    let prefix = Prefix::of(model);
    let start = State::initial(channels);

    let mut finals: Vec<State> = Vec::new();
    if k_cuts == 0 {
        for &v in &cands {
            evals += 1;
            finals.push(extend(&start, 0, l, 0, model, &prefix, costs, m, v, true));
        }
    } else {
        // cells[j]: dominance frontier of prefixes whose last block ends
        // at cuts[j].
        let mut cells: Vec<Vec<State>> = vec![Vec::new(); cuts.len()];
        // Choosing cuts[j] as the stage-th cut needs k_cuts - stage more
        // cuts strictly after it.
        let last_ok = |stage: usize| cuts.len() + stage - k_cuts - 1;
        for j in 0..=last_ok(1) {
            for &v in &cands {
                evals += 1;
                let cand = extend(&start, 0, cuts[j], 0, model, &prefix, costs, m, v, false);
                insert(&mut cells[j], cand, &mut capped);
            }
        }
        for stage in 2..=k_cuts {
            let mut next_cells: Vec<Vec<State>> = vec![Vec::new(); cuts.len()];
            for j_prev in 0..cuts.len() {
                if cells[j_prev].is_empty() {
                    continue;
                }
                for st in &cells[j_prev] {
                    for (j, &c) in cuts.iter().enumerate().take(last_ok(stage) + 1).skip(j_prev + 1)
                    {
                        for &v in &cands {
                            evals += 1;
                            let cand = extend(
                                st,
                                cuts[j_prev],
                                c,
                                stage - 1,
                                model,
                                &prefix,
                                costs,
                                m,
                                v,
                                false,
                            );
                            insert(&mut next_cells[j], cand, &mut capped);
                        }
                    }
                }
            }
            cells = next_cells;
        }
        for (j, cell) in cells.iter().enumerate() {
            for st in cell {
                for &v in &cands {
                    evals += 1;
                    finals.push(extend(st, cuts[j], l, n - 1, model, &prefix, costs, m, v, true));
                }
            }
        }
    }

    // Collapse final states to the (memory, latency) Pareto frontier.
    // For n <= m the whole chain coexists, matching
    // `peak_resident_bytes_m`'s min(m, n)-wide window — with variants,
    // that window holds each block's working set, tracked in `ws_sum`
    // (equal to the chain total under Plain).
    let mut rows: Vec<Row> = finals
        .into_iter()
        .map(|st| Row {
            max_mem_bytes: if n < m { st.ws_sum } else { st.peak },
            predicted_latency_s: st.exec_end,
            points: st.points,
            variants: st.variants,
        })
        .collect();
    rows.sort_by(|a, b| {
        a.max_mem_bytes
            .cmp(&b.max_mem_bytes)
            .then(a.predicted_latency_s.total_cmp(&b.predicted_latency_s))
            .then(a.points.cmp(&b.points))
            .then(a.variants.cmp(&b.variants))
    });
    let mut front: Vec<Row> = Vec::new();
    for r in rows.drain(..) {
        match front.last() {
            Some(last) if r.predicted_latency_s >= last.predicted_latency_s => {}
            _ => front.push(r),
        }
    }
    DpResult { rows: front, evals, capped }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DeviceProfile, Processor, MB};
    use crate::model::LayerInfo;
    use crate::planner::cost::AnalyticCosts;
    use crate::scheduler::partition;

    fn costs() -> AnalyticCosts {
        AnalyticCosts::from_profile(&DeviceProfile::jetson_nx())
    }

    fn model(sizes_mb: &[u64]) -> ModelInfo {
        ModelInfo {
            name: "dp-toy".into(),
            family: "toy".into(),
            layers: sizes_mb
                .iter()
                .enumerate()
                .map(|(i, &s)| LayerInfo {
                    name: format!("l{i}"),
                    kind: "conv".into(),
                    size_bytes: s * MB,
                    depth: 2 + (i as u32 % 5),
                    flops: 500_000_000 + 300_000_000 * (i as u64 % 4),
                    cut_after: true,
                })
                .collect(),
            accuracy: 90.0,
            processor: Processor::Cpu,
        }
    }

    /// Oracle: enumerate every n-block partition with `evaluate_spec`.
    fn oracle_best(m: &ModelInfo, n: usize, spec: &PipelineSpec) -> Option<Row> {
        let dm = crate::delay::DelayModel::from_profile(&DeviceProfile::jetson_nx());
        partition::enumerate_rows(m, n, &dm, spec)
            .into_iter()
            .min_by(|a, b| {
                a.predicted_latency_s
                    .total_cmp(&b.predicted_latency_s)
                    .then(a.max_mem_bytes.cmp(&b.max_mem_bytes))
                    .then(a.points.cmp(&b.points))
            })
    }

    #[test]
    fn dp_best_matches_enumeration_bitwise() {
        let m = model(&[12, 7, 21, 9, 15, 11, 18]);
        let spec = PipelineSpec::default();
        for n in 2..=4 {
            let dp = frontier(&m, n, &costs(), &spec);
            let best = dp.best_within(u64::MAX).unwrap();
            let want = oracle_best(&m, n, &spec).unwrap();
            assert_eq!(best.predicted_latency_s, want.predicted_latency_s, "n={n}");
            assert_eq!(best.max_mem_bytes, want.max_mem_bytes, "n={n}");
        }
    }

    #[test]
    fn dp_rows_evaluate_consistently() {
        // Every frontier row's (mem, latency) must be exactly what the
        // batch evaluator computes for its points.
        let m = model(&[12, 7, 21, 9, 15, 11, 18]);
        let dm = crate::delay::DelayModel::from_profile(&DeviceProfile::jetson_nx());
        for mres in [1usize, 2, 3] {
            let spec = PipelineSpec::with_residency(mres);
            let dp = frontier(&m, 4, &costs(), &spec);
            assert!(!dp.rows.is_empty());
            for r in &dp.rows {
                let (mem, lat) = partition::evaluate_spec(&m, &r.points, &dm, &spec).unwrap();
                assert_eq!(r.max_mem_bytes, mem, "{:?}", r.points);
                assert_eq!(r.predicted_latency_s, lat, "{:?}", r.points);
            }
        }
    }

    #[test]
    fn frontier_is_sorted_and_strictly_improving() {
        let m = model(&[12, 7, 21, 9, 15, 11, 18, 6, 14]);
        let dp = frontier(&m, 5, &costs(), &PipelineSpec::default());
        for w in dp.rows.windows(2) {
            assert!(w[0].max_mem_bytes < w[1].max_mem_bytes);
            assert!(w[0].predicted_latency_s > w[1].predicted_latency_s);
        }
    }

    #[test]
    fn best_within_respects_the_memory_gate() {
        let m = model(&[10, 10, 10, 10, 10, 10]);
        let dp = frontier(&m, 3, &costs(), &PipelineSpec::default());
        // The balanced 2+2+2 split needs a 40 MB adjacent pair.
        let best = dp.best_within(40 * MB).unwrap();
        assert!(best.max_mem_bytes <= 40 * MB);
        assert!(dp.best_within(25 * MB).is_none(), "no 3-split fits 25 MB");
    }

    #[test]
    fn multi_channel_spec_flows_through() {
        let m = model(&[12, 7, 21, 9, 15, 11, 18]);
        let one = frontier(&m, 4, &costs(), &PipelineSpec { residency_m: 4, swap_channels: 1 });
        let two = frontier(&m, 4, &costs(), &PipelineSpec { residency_m: 4, swap_channels: 2 });
        let b1 = one.best_within(u64::MAX).unwrap().predicted_latency_s;
        let b2 = two.best_within(u64::MAX).unwrap().predicted_latency_s;
        assert!(b2 <= b1 + 1e-12, "extra channel can only help: {b2} vs {b1}");
    }

    #[test]
    fn too_few_cuts_yields_empty() {
        let m = model(&[10, 10]);
        assert!(frontier(&m, 4, &costs(), &PipelineSpec::default()).rows.is_empty());
    }

    #[test]
    fn default_policy_is_bit_identical_to_plain_frontier() {
        let m = model(&[12, 7, 21, 9, 15, 11, 18]);
        let spec = PipelineSpec::default();
        for n in 1..=4 {
            let a = frontier(&m, n, &costs(), &spec);
            let b = frontier_with(&m, n, &costs(), &spec, VariantPolicy::default());
            assert_eq!(a.evals, b.evals, "n={n}");
            assert_eq!(a.rows.len(), b.rows.len(), "n={n}");
            for (ra, rb) in a.rows.iter().zip(&b.rows) {
                assert_eq!(ra.max_mem_bytes, rb.max_mem_bytes);
                assert_eq!(ra.predicted_latency_s, rb.predicted_latency_s);
                assert_eq!(ra.points, rb.points);
                assert!(ra.variants.iter().all(|v| *v == SwapVariant::Plain));
            }
        }
    }

    #[test]
    fn auto_codec_never_loses_to_plain() {
        // Plain stays a candidate under Auto, so for every budget the
        // auto frontier's best row is at least as fast as plain's.
        let m = model(&[40, 35, 50, 45, 38, 42]);
        let spec = PipelineSpec::default();
        let plain = frontier(&m, 4, &costs(), &spec);
        let auto = frontier_with(
            &m,
            4,
            &costs(),
            &spec,
            VariantPolicy { codec: crate::pipeline::CodecMode::Auto, tile_max: 1 },
        );
        for r in &plain.rows {
            let best = auto.best_within(r.max_mem_bytes).expect("plain row stays feasible");
            assert!(
                best.predicted_latency_s <= r.predicted_latency_s,
                "auto must not lose at {} bytes: {} vs {}",
                r.max_mem_bytes,
                best.predicted_latency_s,
                r.predicted_latency_s
            );
        }
        // On the NX the codec is a genuine win on IO-bound blocks.
        let b_plain = plain.best_within(u64::MAX).unwrap();
        let b_auto = auto.best_within(u64::MAX).unwrap();
        assert!(b_auto.predicted_latency_s < b_plain.predicted_latency_s);
        assert!(b_auto.variants.contains(&SwapVariant::Compressed));
    }

    #[test]
    fn tiling_extends_the_frontier_below_plain_minimum() {
        let m = model(&[40, 35, 50, 45, 38, 42]);
        let spec = PipelineSpec::default();
        let plain = frontier(&m, 3, &costs(), &spec);
        let tiled = frontier_with(
            &m,
            3,
            &costs(),
            &spec,
            VariantPolicy { codec: crate::pipeline::CodecMode::Off, tile_max: 4 },
        );
        let plain_floor = plain.rows.first().unwrap().max_mem_bytes;
        let tiled_floor = tiled.rows.first().unwrap().max_mem_bytes;
        assert!(
            tiled_floor < plain_floor,
            "tiling must reach below the plain floor: {tiled_floor} vs {plain_floor}"
        );
        // Budgets only plain can't satisfy become feasible.
        assert!(plain.best_within(tiled_floor).is_none());
        assert!(tiled.best_within(tiled_floor).is_some());
    }
}
