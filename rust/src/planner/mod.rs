//! The unified planner subsystem — the single entry point for
//! "model + budget + `PipelineSpec` -> partition + schedule".
//!
//! Planning logic used to be scattered: `scheduler::partition` searched
//! tables (exhaustive for n <= 3, lossy beam beyond), `scheduler::adapt`
//! rebuilt default-spec tables, and `server::multi` rebuilt every
//! tenant's table on each re-partition — while the Fig 9 profiler's
//! measured coefficients never reached any of them. This module owns
//! the three pieces that fix that:
//!
//! * [`cost`] — the [`CostProvider`] seam: [`AnalyticCosts`] (today's
//!   `DelayModel`) and [`MeasuredCosts`] (Fig 9 `Fit`, refined online),
//!   each with a stable fingerprint;
//! * [`dp`] — the exact interval-DP partitioner replacing enumeration
//!   and beam search (O(cuts^2 * n) instead of C(cuts, n-1));
//! * [`cache`] — the [`PlanCache`] keyed by (model, spec, budget band,
//!   fingerprint), shared across tenants, bounded in bytes, invalidated
//!   on cost drift.
//!
//! [`Planner`] composes them: `plan()` answers budget probes from the
//! cache when possible and runs the DP otherwise. The engine owns one
//! planner per [`Engine`](crate::engine::Engine) (shared by every
//! registered tenant); `scheduler::schedule_model_spec` and
//! `scheduler::adapt` route through the same machinery.

pub mod cache;
pub mod cost;
pub mod dp;

pub use cache::{PlanCache, PlanCacheConfig, PlanStats, DEFAULT_PINNED_BAND_BYTES};
pub use cost::{AnalyticCosts, CostObservation, CostProvider, Costs, MeasuredCosts, ReusedCosts};

use std::rc::Rc;

use crate::config::DeviceProfile;
use crate::delay::{profiler, DelayModel};
use crate::model::ModelInfo;
use crate::pipeline::{PipelineSpec, SwapVariant, VariantPolicy};
use crate::scheduler::partition::LookupTable;
use crate::scheduler::{self, Schedule};
use crate::util::hash::fnv1a;

/// Builder-facing choice of cost provider.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CostSource {
    /// Hand-calibrated analytic coefficients (the historical default).
    #[default]
    Analytic,
    /// Fig 9 regression over a measured sweep, refined online.
    Measured,
}

impl CostSource {
    pub fn by_name(name: &str) -> Option<CostSource> {
        match name {
            "analytic" => Some(CostSource::Analytic),
            "measured" => Some(CostSource::Measured),
            _ => None,
        }
    }
}

/// Decode-planning context: what the autoregressive step loop knows that
/// an ordinary inference probe does not. `pinned_bytes` is the KV-cache
/// load currently pinned in the MemSim ledger (it shrinks the swap
/// window the planner may use); `batch` is the number of active
/// sequences one pipelined block sweep serves (each swapped-in block
/// executes `batch` times, amortizing swap-in). The default (0, 1) makes
/// [`Planner::plan_decode`] identical to [`Planner::plan`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlanContext {
    /// Bytes pinned for KV caches, charged against the budget.
    pub pinned_bytes: u64,
    /// Decode batch width (per-step execution reuse per block).
    pub batch: usize,
}

impl Default for PlanContext {
    fn default() -> PlanContext {
        PlanContext { pinned_bytes: 0, batch: 1 }
    }
}

/// Sample count / jitter of the builder-run Fig 9 sweep behind
/// [`CostSource::Measured`]. The small jitter keeps the fit honest
/// (real measurements scatter) while staying within a few percent of
/// the analytic truth.
const MEASURED_SWEEP: (usize, f64) = (240, 0.01);

/// Fold the variant policy into a cost fingerprint. The default policy
/// is the identity, so default-path cache keys are byte-identical to the
/// pre-variant planner's; any wider policy gets its own key space (a
/// codec-aware plan must never answer a plain probe, and vice versa).
fn policy_fp(fp: u64, policy: VariantPolicy) -> u64 {
    if policy.is_default() {
        fp
    } else {
        fnv1a([fp, 0x5641 /* "VA" */, policy.codec as u64, policy.tile_max as u64])
    }
}

/// The planner: cost provider + DP partitioner + shared plan cache.
#[derive(Debug)]
pub struct Planner {
    costs: Costs,
    cache: PlanCache,
    policy: VariantPolicy,
    dp_evals: u64,
    capped_frontiers: u64,
}

impl Planner {
    pub fn new(costs: Costs, cache_cfg: PlanCacheConfig) -> Planner {
        Planner {
            costs,
            cache: PlanCache::new(cache_cfg),
            policy: VariantPolicy::default(),
            dp_evals: 0,
            capped_frontiers: 0,
        }
    }

    /// Set the swap-variant search space (builder style). Plans and
    /// tables made under different policies never share cache entries.
    pub fn with_policy(mut self, policy: VariantPolicy) -> Planner {
        self.policy = policy;
        self
    }

    pub fn set_policy(&mut self, policy: VariantPolicy) {
        self.policy = policy;
    }

    pub fn policy(&self) -> VariantPolicy {
        self.policy
    }

    /// The cache-keying fingerprint: cost fingerprint + variant policy.
    fn eff_fp(&self) -> u64 {
        policy_fp(self.costs.provider().fingerprint(), self.policy)
    }

    /// Analytic planner for a device profile with default cache sizing.
    pub fn analytic(prof: &DeviceProfile) -> Planner {
        Self::new(Costs::Analytic(AnalyticCosts::from_profile(prof)), PlanCacheConfig::default())
    }

    /// Measured planner: runs the Fig 9 sweep + regression against the
    /// profile's simulated device and plans from the fitted model.
    pub fn measured(prof: &DeviceProfile, seed: u64) -> Planner {
        let sweep = profiler::measure_sweep(prof, MEASURED_SWEEP.0, MEASURED_SWEEP.1, seed ^ 0xF19);
        let fit = profiler::fit(&sweep);
        Self::new(
            Costs::Measured(MeasuredCosts::from_fit(&fit, prof)),
            PlanCacheConfig::default(),
        )
    }

    /// Build for a cost source with explicit cache sizing (the engine
    /// builder's path).
    pub fn for_source(
        source: CostSource,
        prof: &DeviceProfile,
        seed: u64,
        cache_cfg: PlanCacheConfig,
    ) -> Planner {
        let mut p = match source {
            CostSource::Analytic => Self::analytic(prof),
            CostSource::Measured => Self::measured(prof, seed),
        };
        p.cache = PlanCache::new(cache_cfg);
        p
    }

    /// The effective delay model behind the current cost provider.
    pub fn delay_model(&self) -> &DelayModel {
        self.costs.provider().delay_model()
    }

    pub fn cost_source(&self) -> &'static str {
        self.costs.provider().name()
    }

    pub fn fingerprint(&self) -> u64 {
        self.costs.provider().fingerprint()
    }

    /// Fold one serving observation into the cost provider (no-op for
    /// analytic costs). On fingerprint drift, cached plans keyed by the
    /// stale fingerprint are dropped.
    pub fn observe(&mut self, obs: &CostObservation) {
        if self.costs.observe(obs) {
            let fp = self.eff_fp();
            self.cache.retain_fingerprint(fp);
        }
    }

    /// Fold one decompress measurement into the cost provider (no-op for
    /// analytic costs). When the decompress coefficient drifts past the
    /// quantization band, the fingerprint moves and every cached plan —
    /// in particular the variant choices made under the stale codec
    /// price — is invalidated.
    pub fn observe_decompress(&mut self, bytes: u64, seen_s: f64) {
        if self.costs.observe_decompress(bytes, seen_s) {
            let fp = self.eff_fp();
            self.cache.retain_fingerprint(fp);
        }
    }

    /// Counter snapshot for reports.
    pub fn stats(&self) -> PlanStats {
        PlanStats {
            cost_source: self.cost_source().to_string(),
            fingerprint: self.fingerprint(),
            hits: self.cache.hits,
            misses: self.cache.misses,
            table_hits: self.cache.table_hits,
            table_misses: self.cache.table_misses,
            evictions: self.cache.evictions,
            invalidations: self.cache.invalidations,
            entries: self.cache.entries(),
            bytes: self.cache.bytes(),
            dp_evals: self.dp_evals,
            capped_frontiers: self.capped_frontiers,
        }
    }

    /// The DP frontier table for (model, n, spec), through the cache
    /// (shared `Rc` — a probe never deep-clones the frontier). Keys
    /// carry the model's chain-content fingerprint alongside its name,
    /// so a same-named model with a different chain never aliases.
    pub fn table(&mut self, model: &ModelInfo, n: usize, spec: &PipelineSpec) -> Rc<LookupTable> {
        let fp = self.eff_fp();
        let chain = cost::model_fingerprint(model);
        if let Some(t) = self.cache.get_table(&model.name, chain, spec, n, fp) {
            return t;
        }
        let out = dp::frontier_with(model, n, self.costs.provider(), spec, self.policy);
        self.dp_evals += out.evals;
        self.capped_frontiers += u64::from(out.capped);
        let t = Rc::new(LookupTable { model: model.name.clone(), n_blocks: n, rows: out.rows });
        self.cache.put_table(&model.name, chain, spec, n, fp, &t);
        t
    }

    /// [`Self::table`] with an explicit provider (the decode path's
    /// batch-scaled costs). Tables are keyed by the provider's own
    /// fingerprint, so batch-2 and batch-8 frontiers never alias each
    /// other or the plain tables.
    fn table_with(
        &mut self,
        model: &ModelInfo,
        n: usize,
        spec: &PipelineSpec,
        costs: &dyn CostProvider,
    ) -> Rc<LookupTable> {
        let fp = policy_fp(costs.fingerprint(), self.policy);
        let chain = cost::model_fingerprint(model);
        if let Some(t) = self.cache.get_table(&model.name, chain, spec, n, fp) {
            return t;
        }
        let out = dp::frontier_with(model, n, costs, spec, self.policy);
        self.dp_evals += out.evals;
        self.capped_frontiers += u64::from(out.capped);
        let t = Rc::new(LookupTable { model: model.name.clone(), n_blocks: n, rows: out.rows });
        self.cache.put_table(&model.name, chain, spec, n, fp, &t);
        t
    }

    /// Pre-build frontier tables for a block-count range (the adaptive
    /// scheduler's offline phase).
    pub fn warm(&mut self, model: &ModelInfo, n_range: std::ops::RangeInclusive<usize>, spec: &PipelineSpec) {
        for n in n_range {
            let _ = self.table(model, n, spec);
        }
    }

    /// Plan one model into one budget under a pipeline spec: answer from
    /// the plan cache when possible, otherwise run the n-walk over DP
    /// frontier tables (themselves cached) and remember the result.
    pub fn plan(
        &mut self,
        model: &ModelInfo,
        budget: u64,
        spec: &PipelineSpec,
    ) -> Result<Schedule, String> {
        let fp = self.eff_fp();
        let chain = cost::model_fingerprint(model);
        if let Some(s) = self.cache.get_plan(&model.name, chain, spec, budget, fp) {
            return Ok(s);
        }
        let dm = self.delay_model().clone();
        let policy = self.policy;
        let sched = {
            let mut table_for = |n: usize| self.table(model, n, spec);
            plan_walk(model, budget, spec, &dm, policy, &mut table_for)?
        };
        self.cache.put_plan(&model.name, chain, spec, budget, fp, &sched);
        Ok(sched)
    }

    /// Decode-aware planning: [`Self::plan`] with the per-step reuse
    /// dimension and the KV-reduced swap window.
    ///
    /// The effective budget is reduced by the *ceiling* of the pinned
    /// band `ctx.pinned_bytes` falls in (multiples of
    /// [`DEFAULT_PINNED_BAND_BYTES`]), so every probe within a band is
    /// an exact cache key match and the resulting plan stays feasible as
    /// KV grows toward the band edge — growth re-plans are cache probes,
    /// not recomputes. Execution costs are scaled by `ctx.batch` through
    /// [`ReusedCosts`], so the interval DP trades partition granularity
    /// against the batch-amortized swap economics. The returned
    /// schedule's `budget_bytes`/`peak_bytes` are relative to the
    /// effective (KV-reduced) budget. With `ctx == PlanContext::default()`
    /// this is byte-identical to [`Self::plan`] — same keys, same plans.
    pub fn plan_decode(
        &mut self,
        model: &ModelInfo,
        budget: u64,
        spec: &PipelineSpec,
        ctx: PlanContext,
    ) -> Result<Schedule, String> {
        let pinned_band = if ctx.pinned_bytes == 0 {
            0
        } else {
            ctx.pinned_bytes / DEFAULT_PINNED_BAND_BYTES + 1
        };
        let eff = budget.saturating_sub(pinned_band * DEFAULT_PINNED_BAND_BYTES);
        if eff == 0 {
            return Err(format!(
                "{}: pinned KV load {} B leaves no swap window under budget {} B",
                model.name, ctx.pinned_bytes, budget
            ));
        }
        let batch = ctx.batch.max(1);
        let chain = cost::model_fingerprint(model);
        let rc = ReusedCosts::new(self.costs.provider(), batch);
        let fp = policy_fp(rc.fingerprint(), self.policy);
        if let Some(s) =
            self.cache.get_plan_at(&model.name, chain, spec, eff, fp, pinned_band, batch)
        {
            return Ok(s);
        }
        let dm = rc.delay_model().clone();
        let policy = self.policy;
        let sched = {
            let mut table_for = |n: usize| self.table_with(model, n, spec, &rc);
            plan_walk(model, eff, spec, &dm, policy, &mut table_for)?
        };
        self.cache.put_plan_at(&model.name, chain, spec, eff, fp, pinned_band, batch, &sched);
        Ok(sched)
    }
}

/// One-shot, uncached planning with an explicit cost provider — the
/// compatibility path behind `scheduler::schedule_model_spec` (identical
/// decisions to a fresh [`Planner`], without cache state).
pub fn plan_uncached(
    costs: &dyn CostProvider,
    model: &ModelInfo,
    budget: u64,
    spec: &PipelineSpec,
) -> Result<Schedule, String> {
    plan_uncached_policy(costs, model, budget, spec, VariantPolicy::default())
}

/// [`plan_uncached`] under an explicit variant policy (identical
/// decisions to a fresh `Planner::with_policy`, without cache state).
pub fn plan_uncached_policy(
    costs: &dyn CostProvider,
    model: &ModelInfo,
    budget: u64,
    spec: &PipelineSpec,
    policy: VariantPolicy,
) -> Result<Schedule, String> {
    let dm = costs.delay_model().clone();
    let mut table_for = |n: usize| {
        let out = dp::frontier_with(model, n, costs, spec, policy);
        Rc::new(LookupTable { model: model.name.clone(), n_blocks: n, rows: out.rows })
    };
    plan_walk(model, budget, spec, &dm, policy, &mut table_for)
}

/// The shared budget walk (paper §6.2.2): whole-model fast path, then
/// n = ceil(m*s/b) growing until a feasible frontier row exists. The
/// table supplier abstracts cached vs one-shot frontier construction.
fn plan_walk(
    model: &ModelInfo,
    budget: u64,
    spec: &PipelineSpec,
    dm: &DelayModel,
    policy: VariantPolicy,
    table_for: &mut dyn FnMut(usize) -> Rc<LookupTable>,
) -> Result<Schedule, String> {
    let usable = scheduler::usable_budget(model, budget);
    let s = model.size_bytes();
    if s <= usable {
        // Whole-model fast path: nothing swaps in steady state, so no
        // variant applies — the single resident block is always Plain.
        let b = model.single_block();
        return Ok(Schedule {
            model: model.name.clone(),
            budget_bytes: budget,
            n_blocks: 1,
            points: vec![],
            predicted_latency_s: dm.t_in(&b) + dm.t_ex(&b, model.processor),
            peak_bytes: s,
            variants: vec![SwapVariant::Plain],
        });
    }
    if usable == 0 {
        return Err(format!("{}: budget {} infeasible", model.name, budget));
    }
    // Feasibility floor: the finest legal partition minimizes the
    // m-window peak (merging segments only grows windows), so a budget
    // under the atomic peak is infeasible at EVERY n — error now
    // instead of walking the whole n range through the DP. The floor is
    // policy-aware: tiling shrinks each segment's working set, so a
    // tiling policy accepts budgets the plain floor rejects
    // (`scheduler::minimal_budget_policy` advertises the same bound).
    let cuts = model.legal_cut_points();
    if scheduler::atomic_peak_bytes_policy(model, spec, policy) > usable {
        return Err(format!(
            "{}: no feasible partition within {} MB",
            model.name,
            usable / 1_000_000
        ));
    }
    // The floor check above proved the finest partition fits, so the
    // walk must reach it: clamp the n = ceil(m*s/b) starting point INTO
    // [2, max_n] (the historical clamp to max_n + 1 skipped the loop
    // entirely when the formula overshot, wrongly reporting feasible
    // budgets as infeasible). max_n >= 2 here: usable < model size with
    // a feasible atomic peak implies at least one legal cut.
    let max_n = cuts.len() + 1;
    let mut n = scheduler::num_blocks_m(s, usable, spec.residency_m).clamp(2, max_n);
    while n <= max_n {
        let table = table_for(n);
        if let Some(row) = best_row(table.as_ref(), usable) {
            return Ok(Schedule {
                model: model.name.clone(),
                budget_bytes: budget,
                n_blocks: n,
                points: row.points.clone(),
                predicted_latency_s: row.predicted_latency_s,
                peak_bytes: row.max_mem_bytes,
                variants: row.variants.clone(),
            });
        }
        n += 1;
    }
    Err(format!(
        "{}: no feasible partition within {} MB",
        model.name,
        usable / 1_000_000
    ))
}

/// Canonical best-row selection: minimal latency, then minimal memory,
/// then lexicographically smallest points (deterministic across table
/// sources; on DP frontiers this is simply the last feasible row).
fn best_row(table: &LookupTable, usable: u64) -> Option<&crate::scheduler::partition::Row> {
    table
        .rows
        .iter()
        .filter(|r| r.max_mem_bytes <= usable)
        .min_by(|a, b| {
            a.predicted_latency_s
                .total_cmp(&b.predicted_latency_s)
                .then(a.max_mem_bytes.cmp(&b.max_mem_bytes))
                .then(a.points.cmp(&b.points))
        })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DeviceProfile, MB};
    use crate::model::families;

    #[test]
    fn planner_plan_matches_schedule_model_spec() {
        // The cached planner and the one-shot scheduler path must make
        // identical decisions (the planner IS the scheduler now).
        let prof = DeviceProfile::jetson_nx();
        let dm = DelayModel::from_profile(&prof);
        let mut p = Planner::analytic(&prof);
        for budget in [102 * MB, 136 * MB, 300 * MB] {
            let spec = PipelineSpec::default();
            let a = p.plan(&families::resnet101(), budget, &spec).unwrap();
            let b = scheduler::schedule_model_spec(
                &families::resnet101(),
                budget,
                &dm,
                &prof,
                &spec,
            )
            .unwrap();
            assert_eq!(a.points, b.points, "budget {budget}");
            assert_eq!(a.peak_bytes, b.peak_bytes);
            assert_eq!(a.predicted_latency_s, b.predicted_latency_s);
            assert_eq!(a.n_blocks, b.n_blocks);
        }
    }

    #[test]
    fn repeat_probes_hit_the_cache() {
        let prof = DeviceProfile::jetson_nx();
        let mut p = Planner::analytic(&prof);
        let m = families::resnet101();
        let spec = PipelineSpec::default();
        let first = p.plan(&m, 102 * MB, &spec).unwrap();
        let s0 = p.stats();
        assert_eq!(s0.hits, 0);
        assert!(s0.misses >= 1);
        assert!(s0.dp_evals > 0);
        let evals_after_first = s0.dp_evals;
        let again = p.plan(&m, 102 * MB, &spec).unwrap();
        let s1 = p.stats();
        assert_eq!(s1.hits, 1);
        assert_eq!(s1.dp_evals, evals_after_first, "a cache hit runs no DP");
        assert_eq!(first.points, again.points);
        // A different spec is a different plan key.
        let m3 = p.plan(&m, 150 * MB, &PipelineSpec::with_residency(3)).unwrap();
        assert!(m3.n_blocks > 1);
    }

    #[test]
    fn measured_planner_plans_sanely() {
        let prof = DeviceProfile::jetson_nx();
        let mut p = Planner::measured(&prof, 7);
        assert_eq!(p.cost_source(), "measured");
        let s = p.plan(&families::resnet101(), 102 * MB, &PipelineSpec::default()).unwrap();
        // The fitted model tracks the analytic one closely, so the
        // block count lands in the same neighborhood as the paper's 4.
        assert!((3..=5).contains(&s.n_blocks), "{s:?}");
        assert!(s.peak_bytes <= scheduler::usable_budget(&families::resnet101(), 102 * MB));
    }

    #[test]
    fn observation_drift_invalidates_cached_plans() {
        let prof = DeviceProfile::jetson_nx();
        let mut p = Planner::measured(&prof, 7);
        let m = families::resnet101();
        let spec = PipelineSpec::default();
        p.plan(&m, 102 * MB, &spec).unwrap();
        assert!(p.stats().entries > 0);
        let fp0 = p.fingerprint();
        // Hammer a 3x swap slowdown until the fingerprint moves.
        let dmc = p.delay_model().clone();
        for _ in 0..16 {
            p.observe(&CostObservation {
                n_blocks: 4,
                bytes: m.size_bytes(),
                depth: m.total_depth(),
                flops: m.total_flops(),
                proc: m.processor,
                swap_s: 3.0 * (dmc.alpha_s_per_byte * m.size_bytes() as f64 + dmc.dma_setup_s * 4.0),
                assembly_s: dmc.beta_s_per_depth * m.total_depth() as f64,
                compute_s: dmc.gamma_cpu_s_per_flop * m.total_flops() as f64
                    + dmc.dispatch_s_per_block * 4.0,
            });
        }
        assert_ne!(p.fingerprint(), fp0, "3x drift must move the fingerprint");
        let st = p.stats();
        assert!(st.invalidations > 0, "{st:?}");
        // Planning still works under the drifted model.
        let s = p.plan(&m, 102 * MB, &spec).unwrap();
        assert!(s.n_blocks >= 2);
    }

    #[test]
    fn same_name_different_chain_never_aliases() {
        // Cache keys carry the chain-content fingerprint: a "retrained"
        // model re-registered under the same name with a different
        // chain must re-plan, not reuse the old partition.
        let prof = DeviceProfile::jetson_nx();
        let mut p = Planner::analytic(&prof);
        let a = families::resnet101();
        let spec = PipelineSpec::default();
        let s1 = p.plan(&a, 120 * MB, &spec).unwrap();
        let mut b = families::resnet101();
        for l in &mut b.layers {
            l.size_bytes = l.size_bytes * 3 / 2;
        }
        let s2 = p.plan(&b, 120 * MB, &spec).unwrap();
        assert!(s2.n_blocks > s1.n_blocks, "{} vs {}", s2.n_blocks, s1.n_blocks);
        let blocks = b.create_blocks(&s2.points).unwrap();
        let sizes: Vec<u64> = blocks.iter().map(|x| x.size_bytes).collect();
        assert!(
            crate::pipeline::peak_resident_bytes_m(&sizes, 2)
                <= scheduler::usable_budget(&b, 120 * MB),
            "the 1.5x chain must be planned against ITS OWN sizes"
        );
        // The original model still hits its own entry.
        let evals = p.stats().dp_evals;
        let s1_again = p.plan(&a, 120 * MB, &spec).unwrap();
        assert_eq!(s1_again.points, s1.points);
        assert_eq!(p.stats().dp_evals, evals);
    }

    #[test]
    fn plan_decode_default_context_is_plain_plan() {
        let prof = DeviceProfile::jetson_nx();
        let mut p = Planner::analytic(&prof);
        let m = families::resnet101();
        let spec = PipelineSpec::default();
        let a = p.plan(&m, 120 * MB, &spec).unwrap();
        // The default-context decode probe hits the SAME cache entry.
        let hits = p.stats().hits;
        let b = p.plan_decode(&m, 120 * MB, &spec, PlanContext::default()).unwrap();
        assert_eq!(p.stats().hits, hits + 1, "shared key with plain plan()");
        assert_eq!(a.points, b.points);
        assert_eq!(a.predicted_latency_s, b.predicted_latency_s);
        assert_eq!(a.peak_bytes, b.peak_bytes);
    }

    #[test]
    fn plan_decode_shrinks_window_by_pinned_band_ceiling() {
        let prof = DeviceProfile::jetson_nx();
        let mut p = Planner::analytic(&prof);
        let m = families::llama7b();
        let spec = PipelineSpec::default();
        let budget = 2 * 1024 * MB;
        let plain = p.plan_decode(&m, budget, &spec, PlanContext::default()).unwrap();
        let pinned = 300 * MB;
        let ctx = PlanContext { pinned_bytes: pinned, batch: 1 };
        let s = p.plan_decode(&m, budget, &spec, ctx).unwrap();
        let band = pinned / DEFAULT_PINNED_BAND_BYTES + 1;
        let eff = budget - band * DEFAULT_PINNED_BAND_BYTES;
        assert_eq!(s.budget_bytes, eff, "planned against the KV-reduced window");
        assert!(s.peak_bytes <= scheduler::usable_budget(&m, eff));
        assert!(s.n_blocks >= plain.n_blocks, "less window, same or finer partition");
        // KV growth within the band is a pure cache probe.
        let hits = p.stats().hits;
        let evals = p.stats().dp_evals;
        let grown = PlanContext { pinned_bytes: pinned + MB, batch: 1 };
        let s2 = p.plan_decode(&m, budget, &spec, grown).unwrap();
        assert_eq!(p.stats().hits, hits + 1);
        assert_eq!(p.stats().dp_evals, evals, "no DP on a within-band re-plan");
        assert_eq!(s2.points, s.points);
        // Crossing the band edge re-plans against a smaller window.
        let crossed = PlanContext { pinned_bytes: band * DEFAULT_PINNED_BAND_BYTES + 1, batch: 1 };
        let s3 = p.plan_decode(&m, budget, &spec, crossed).unwrap();
        assert_eq!(s3.budget_bytes, eff - DEFAULT_PINNED_BAND_BYTES);
    }

    #[test]
    fn plan_decode_batch_amortizes_swap_per_token() {
        // The reuse dimension: at batch b the planned sweep latency is
        // less than b times the batch-1 latency on an IO-bound chain
        // (swap-in is paid once per block, execution b times).
        let prof = DeviceProfile::jetson_nx();
        let mut p = Planner::analytic(&prof);
        let m = families::llama7b();
        let spec = PipelineSpec::default();
        let budget = 2 * 1024 * MB;
        let s1 = p.plan_decode(&m, budget, &spec, PlanContext::default()).unwrap();
        let s8 = p
            .plan_decode(&m, budget, &spec, PlanContext { pinned_bytes: 0, batch: 8 })
            .unwrap();
        let per_tok_1 = s1.predicted_latency_s;
        let per_tok_8 = s8.predicted_latency_s / 8.0;
        assert!(
            per_tok_8 < per_tok_1 / 2.0,
            "batch-8 decode must amortize: {per_tok_8} vs {per_tok_1}"
        );
        // Distinct batch widths never alias in the cache.
        let s8_again = p
            .plan_decode(&m, budget, &spec, PlanContext { pinned_bytes: 0, batch: 8 })
            .unwrap();
        assert_eq!(s8_again.points, s8.points);
        assert_eq!(s8_again.predicted_latency_s, s8.predicted_latency_s);
    }

    #[test]
    fn plan_decode_kv_overload_is_a_graceful_error() {
        let prof = DeviceProfile::jetson_nx();
        let mut p = Planner::analytic(&prof);
        let m = families::llama7b();
        let spec = PipelineSpec::default();
        let budget = 2 * 1024 * MB;
        let ctx = PlanContext { pinned_bytes: budget, batch: 2 };
        let err = p.plan_decode(&m, budget, &spec, ctx).unwrap_err();
        assert!(err.contains("swap window"), "{err}");
    }

    #[test]
    fn variant_policy_keys_its_own_cache_space() {
        use crate::pipeline::CodecMode;
        let prof = DeviceProfile::jetson_nx();
        let m = families::resnet101();
        let spec = PipelineSpec::default();
        let budget = 102 * MB;
        let mut plain = Planner::analytic(&prof);
        let base = plain.plan(&m, budget, &spec).unwrap();
        assert!(base.variants.iter().all(|v| *v == SwapVariant::Plain));
        let mut auto = Planner::analytic(&prof)
            .with_policy(VariantPolicy { codec: CodecMode::Auto, tile_max: 1 });
        let lz = auto.plan(&m, budget, &spec).unwrap();
        // Plain stays a candidate, so auto never predicts slower; on the
        // NX's IO-bound ResNet blocks the codec is a strict win.
        assert!(lz.predicted_latency_s < base.predicted_latency_s, "{lz:?}");
        assert!(lz.variants.contains(&SwapVariant::Compressed));
        assert_eq!(lz.variants.len(), lz.n_blocks);
        // The cached auto plan answers auto probes only.
        let again = auto.plan(&m, budget, &spec).unwrap();
        assert_eq!(auto.stats().hits, 1);
        assert_eq!(again.points, lz.points);
        assert_eq!(again.variants, lz.variants);
        // Uncached policy planning makes the identical decision.
        let costs = AnalyticCosts::from_profile(&prof);
        let one_shot = plan_uncached_policy(
            &costs,
            &m,
            budget,
            &spec,
            VariantPolicy { codec: CodecMode::Auto, tile_max: 1 },
        )
        .unwrap();
        assert_eq!(one_shot.points, lz.points);
        assert_eq!(one_shot.variants, lz.variants);
        assert_eq!(one_shot.predicted_latency_s, lz.predicted_latency_s);
    }

    #[test]
    fn tiling_policy_accepts_budgets_below_the_plain_floor() {
        use crate::pipeline::CodecMode;
        let prof = DeviceProfile::jetson_nx();
        let m = crate::model::ModelInfo {
            name: "tile-toy".into(),
            family: "toy".into(),
            layers: (0..8)
                .map(|i| crate::model::LayerInfo {
                    name: format!("l{i}"),
                    kind: "conv".into(),
                    size_bytes: 30 * MB,
                    depth: 4,
                    flops: 2_000_000_000,
                    cut_after: true,
                })
                .collect(),
            accuracy: 90.0,
            processor: crate::config::Processor::Cpu,
        };
        let spec = PipelineSpec::default();
        let plain_min = scheduler::minimal_budget_spec(&m, &spec);
        let policy = VariantPolicy { codec: CodecMode::Off, tile_max: 8 };
        let tiled_min = scheduler::minimal_budget_policy(&m, &spec, policy);
        assert!(tiled_min < plain_min, "{tiled_min} !< {plain_min}");
        // A budget between the floors: plain rejects, tiling plans.
        let budget = (tiled_min + plain_min) / 2;
        let mut p = Planner::analytic(&prof);
        assert!(p.plan(&m, budget, &spec).is_err(), "below the plain floor");
        let mut t = Planner::analytic(&prof).with_policy(policy);
        let s = t.plan(&m, budget, &spec).unwrap();
        assert!(s.variants.iter().any(|v| matches!(v, SwapVariant::Tiled { .. })));
        assert!(s.peak_bytes <= scheduler::usable_budget(&m, budget));
    }

    #[test]
    fn plan_uncached_equals_cached_planner() {
        let prof = DeviceProfile::jetson_nx();
        let costs = AnalyticCosts::from_profile(&prof);
        let mut p = Planner::analytic(&prof);
        let m = families::resnet101();
        let spec = PipelineSpec::with_residency(3);
        let a = plan_uncached(&costs, &m, 150 * MB, &spec).unwrap();
        let b = p.plan(&m, 150 * MB, &spec).unwrap();
        assert_eq!(a.points, b.points);
        assert_eq!(a.peak_bytes, b.peak_bytes);
        assert_eq!(a.predicted_latency_s, b.predicted_latency_s);
    }
}
