//! Cost providers: where the planner's per-block delay predictions come
//! from (paper §6.1 / Fig 9).
//!
//! [`CostProvider`] is the seam between "how blocks cost" and "how
//! partitions are chosen": [`AnalyticCosts`] wraps the hand-calibrated
//! [`DelayModel`] (the historical path), [`MeasuredCosts`] is fed by the
//! Fig 9 regression ([`Fit`] -> [`DelayModel::from_fit`]) and refined
//! online from serving observations ([`CostObservation`]). Both expose a
//! stable [`fingerprint`](CostProvider::fingerprint) that keys the plan
//! cache — when measured coefficients drift past the quantization band,
//! the fingerprint moves and cached plans invalidate.

use crate::config::{DeviceProfile, Processor};
use crate::delay::profiler::Fit;
use crate::delay::DelayModel;
use crate::model::{BlockInfo, ModelInfo};
use crate::pipeline::{BlockTimes, SwapVariant};
// The shared content hash: cost fingerprints and the block store's
// on-disk keys must agree, so both pull the same `util::hash::fnv1a`
// (its stability tests pin the constants).
use crate::util::hash::fnv1a;

/// Stable fingerprint of a model's chain content (layer sizes, depths,
/// FLOPs, cut legality). Cache keys carry it alongside the model name:
/// two models that share a name but not a chain (e.g. a re-exported
/// artifact) must never alias each other's cached partitions.
pub fn model_fingerprint(model: &ModelInfo) -> u64 {
    fnv1a(model.layers.iter().flat_map(|l| {
        [l.size_bytes, l.depth as u64, l.flops, l.cut_after as u64]
    }))
}

fn delay_model_words(dm: &DelayModel) -> [u64; 10] {
    [
        dm.alpha_s_per_byte.to_bits(),
        dm.beta_s_per_depth.to_bits(),
        dm.gamma_cpu_s_per_flop.to_bits(),
        dm.gamma_gpu_s_per_flop.to_bits(),
        dm.eta_s_per_depth.to_bits(),
        dm.gc_s.to_bits(),
        dm.dma_setup_s.to_bits(),
        dm.dispatch_s_per_block.to_bits(),
        dm.decompress_s_per_byte.to_bits(),
        dm.tile_dispatch_s.to_bits(),
    ]
}

/// A source of per-block delay predictions for the planner.
pub trait CostProvider {
    /// Provider name for reports ("analytic" | "measured").
    fn name(&self) -> &'static str;

    /// The effective delay model backing the predictions.
    fn delay_model(&self) -> &DelayModel;

    /// Stable identity of the current predictions: equal fingerprints
    /// guarantee equal [`block_times`](Self::block_times) for every
    /// block, so plans keyed by it stay valid until it moves.
    fn fingerprint(&self) -> u64;

    /// Predicted (t_in, t_ex, t_out) for one block — exactly the triple
    /// `partition::evaluate_spec` feeds the pipeline timeline.
    fn block_times(&self, b: &BlockInfo, proc: Processor) -> BlockTimes {
        let dm = self.delay_model();
        BlockTimes { t_in: dm.t_in(b), t_ex: dm.t_ex(b, proc), t_out: dm.t_out(b) }
    }

    /// Predicted delays for one block swapped under a specific variant
    /// (DESIGN.md §13). `Plain` is exactly [`block_times`](Self::block_times),
    /// so the default planner path is bit-identical to the pre-variant one.
    ///
    /// `Compressed` trades IO bytes for CPU: the wire carries
    /// `ceil(size * PLANNED_RATIO)` bytes at the swap bandwidth, then the
    /// CPU pays `decompress_s_per_byte` per *uncompressed* byte. Whether
    /// that trade wins is device-dependent — the NX's Carmel cores
    /// decompress faster than the saved IO, the Nano's A57s don't.
    ///
    /// `Tiled { t }` splits the read into `t` serial sub-reads (t DMA
    /// setups instead of one) and adds `tile_dispatch_s` per extra tile
    /// to execution: strictly slower than `Plain`, but its working set is
    /// two tiles instead of the whole block, so it survives dominance
    /// pruning as the low-memory end of the frontier.
    fn variant_times(&self, b: &BlockInfo, proc: Processor, v: SwapVariant) -> BlockTimes {
        let base = self.block_times(b, proc);
        let dm = self.delay_model();
        match v {
            SwapVariant::Plain => base,
            SwapVariant::Compressed => {
                let wire = (b.size_bytes as f64 * crate::codec::PLANNED_RATIO).ceil();
                BlockTimes {
                    t_in: dm.dma_setup_s
                        + dm.alpha_s_per_byte * wire
                        + dm.beta_s_per_depth * b.depth as f64
                        + dm.decompress_s_per_byte * b.size_bytes as f64,
                    ..base
                }
            }
            SwapVariant::Tiled { t } => {
                let extra = t.saturating_sub(1) as f64;
                BlockTimes {
                    t_in: base.t_in + dm.dma_setup_s * extra,
                    t_ex: base.t_ex + dm.tile_dispatch_s * extra,
                    ..base
                }
            }
        }
    }
}

/// The hand-calibrated analytic cost model (today's `DelayModel` path).
#[derive(Debug, Clone)]
pub struct AnalyticCosts {
    dm: DelayModel,
    fp: u64,
}

impl AnalyticCosts {
    pub fn new(dm: DelayModel) -> AnalyticCosts {
        let fp = fnv1a(delay_model_words(&dm));
        AnalyticCosts { dm, fp }
    }

    pub fn from_profile(prof: &DeviceProfile) -> AnalyticCosts {
        Self::new(DelayModel::from_profile(prof))
    }
}

impl CostProvider for AnalyticCosts {
    fn name(&self) -> &'static str {
        "analytic"
    }

    fn delay_model(&self) -> &DelayModel {
        &self.dm
    }

    fn fingerprint(&self) -> u64 {
        self.fp
    }
}

/// One serving observation feeding online refinement of measured costs:
/// what one full inference pass actually cost, against the chain totals
/// that predicted it. Built from `InferenceReport`s (engine), batch
/// completions (`server::multi`), or `pipeline::real` run reports.
#[derive(Debug, Clone)]
pub struct CostObservation {
    /// Blocks the pass executed (scales the fixed per-block costs).
    pub n_blocks: usize,
    /// Total parameter bytes swapped in.
    pub bytes: u64,
    /// Total parameter depth assembled.
    pub depth: u32,
    /// Total FLOPs executed.
    pub flops: u64,
    pub proc: Processor,
    /// Measured swap-in I/O seconds (sum over blocks).
    pub swap_s: f64,
    /// Measured skeleton-assembly seconds (sum over blocks).
    pub assembly_s: f64,
    /// Measured execution seconds (sum over blocks).
    pub compute_s: f64,
}

/// EMA weight for online refinement: one observation moves a scale 20%
/// of the way toward the observed/predicted ratio.
const OBS_WEIGHT: f64 = 0.2;

/// Refinement ratios are clamped to this band so one garbage sample
/// (cold cache, preempted worker) cannot wreck the model.
const RATIO_CLAMP: (f64, f64) = (0.25, 4.0);

/// Fingerprint quantization: scales are bucketed at 1/64 (~1.6%), so
/// sub-bucket drift refines predictions without thrashing the plan
/// cache; crossing a bucket edge moves the fingerprint and invalidates.
const FP_QUANTUM: f64 = 64.0;

/// Measured costs: seeded by the Fig 9 regression, refined online.
#[derive(Debug, Clone)]
pub struct MeasuredCosts {
    /// The fitted base model (Fig 9 sweep -> `DelayModel::from_fit`).
    base: DelayModel,
    /// Effective model = base with the refinement scales applied.
    dm: DelayModel,
    /// Online refinement factors on the three delay laws.
    scale_in: f64,
    scale_asm: f64,
    scale_ex: f64,
    /// Refinement factor on the codec's decompress law (fed by
    /// [`observe_decompress`](Self::observe_decompress), not by the
    /// three-law [`CostObservation`] — decompress CPU time is measured
    /// separately on the swap-in path).
    scale_dec: f64,
    observations: u64,
    fp: u64,
}

impl MeasuredCosts {
    /// Seed from a Fig 9 fit against a device profile.
    pub fn from_fit(fit: &Fit, prof: &DeviceProfile) -> MeasuredCosts {
        Self::from_delay_model(DelayModel::from_fit(fit, prof))
    }

    /// Seed from an already-fitted delay model.
    pub fn from_delay_model(base: DelayModel) -> MeasuredCosts {
        let mut mc = MeasuredCosts {
            dm: base.clone(),
            base,
            scale_in: 1.0,
            scale_asm: 1.0,
            scale_ex: 1.0,
            scale_dec: 1.0,
            observations: 0,
            fp: 0,
        };
        mc.rebuild();
        mc
    }

    /// Current (swap-in, assembly, execution) refinement scales.
    pub fn scales(&self) -> (f64, f64, f64) {
        (self.scale_in, self.scale_asm, self.scale_ex)
    }

    pub fn observations(&self) -> u64 {
        self.observations
    }

    /// Fold one observation into the refinement scales. Returns true
    /// when the fingerprint moved (the caller must invalidate plans).
    pub fn observe(&mut self, obs: &CostObservation) -> bool {
        if obs.n_blocks == 0 {
            return false;
        }
        let n = obs.n_blocks as f64;
        // Predictions under the BASE model, so the scales stay absolute
        // (an EMA toward observed/base, not a compounding random walk).
        let pred_in = self.base.alpha_s_per_byte * obs.bytes as f64 + self.base.dma_setup_s * n;
        let pred_asm = self.base.beta_s_per_depth * obs.depth as f64;
        let pred_ex = match obs.proc {
            Processor::Cpu => self.base.gamma_cpu_s_per_flop,
            Processor::Gpu => self.base.gamma_gpu_s_per_flop,
        } * obs.flops as f64
            + self.base.dispatch_s_per_block * n;
        let fold = |scale: &mut f64, pred: f64, seen: f64| {
            if pred > 0.0 && seen > 0.0 {
                let r = (seen / pred).clamp(RATIO_CLAMP.0, RATIO_CLAMP.1);
                *scale = (1.0 - OBS_WEIGHT) * *scale + OBS_WEIGHT * r;
            }
        };
        fold(&mut self.scale_in, pred_in, obs.swap_s);
        fold(&mut self.scale_asm, pred_asm, obs.assembly_s);
        fold(&mut self.scale_ex, pred_ex, obs.compute_s);
        self.observations += 1;
        let old_fp = self.fp;
        self.rebuild();
        self.fp != old_fp
    }

    /// Fold one decompress measurement (`seen_s` CPU seconds to inflate
    /// `bytes` uncompressed bytes) into the codec refinement scale, with
    /// the same EMA / clamp / quantization machinery as [`observe`](Self::observe).
    /// Returns true when the fingerprint moved — cached variant choices
    /// made under the old decompress coefficient are then stale (a plan
    /// that chose Compressed because decompression looked cheap must not
    /// survive the discovery that it isn't).
    pub fn observe_decompress(&mut self, bytes: u64, seen_s: f64) -> bool {
        let pred = self.base.decompress_s_per_byte * bytes as f64;
        if pred <= 0.0 || seen_s <= 0.0 {
            return false;
        }
        let r = (seen_s / pred).clamp(RATIO_CLAMP.0, RATIO_CLAMP.1);
        self.scale_dec = (1.0 - OBS_WEIGHT) * self.scale_dec + OBS_WEIGHT * r;
        self.observations += 1;
        let old_fp = self.fp;
        self.rebuild();
        self.fp != old_fp
    }

    /// Re-derive the effective model and fingerprint from the scales.
    /// The effective model uses the QUANTIZED scales, so two states with
    /// equal fingerprints predict identically (the fingerprint contract).
    fn rebuild(&mut self) {
        let q = |s: f64| (s * FP_QUANTUM).round() / FP_QUANTUM;
        let (qi, qa, qe, qd) =
            (q(self.scale_in), q(self.scale_asm), q(self.scale_ex), q(self.scale_dec));
        self.dm = DelayModel {
            alpha_s_per_byte: self.base.alpha_s_per_byte * qi,
            beta_s_per_depth: self.base.beta_s_per_depth * qa,
            gamma_cpu_s_per_flop: self.base.gamma_cpu_s_per_flop * qe,
            gamma_gpu_s_per_flop: self.base.gamma_gpu_s_per_flop * qe,
            eta_s_per_depth: self.base.eta_s_per_depth,
            gc_s: self.base.gc_s,
            dma_setup_s: self.base.dma_setup_s,
            dispatch_s_per_block: self.base.dispatch_s_per_block,
            decompress_s_per_byte: self.base.decompress_s_per_byte * qd,
            tile_dispatch_s: self.base.tile_dispatch_s,
        };
        self.fp = fnv1a(delay_model_words(&self.dm).into_iter().chain([1u64]));
    }
}

impl CostProvider for MeasuredCosts {
    fn name(&self) -> &'static str {
        "measured"
    }

    fn delay_model(&self) -> &DelayModel {
        &self.dm
    }

    fn fingerprint(&self) -> u64 {
        self.fp
    }
}

/// Decode-batch cost wrapper: the per-step reuse dimension of the
/// planner (LLM continuous batching). In autoregressive decode one
/// pipelined block sweep serves every active sequence, so each swapped-in
/// block executes `reuse` times before it leaves — t_in/t_out are paid
/// once but t_ex scales with the batch width. Since
/// `t_ex = gamma * flops + dispatch`, scaling gamma (both processors) and
/// the per-block dispatch cost by `reuse` yields exactly `t_ex * reuse`
/// through the unmodified [`DelayModel`] laws, so the interval DP and the
/// whole-model fast path both see the amortized economics with no special
/// cases.
#[derive(Debug, Clone)]
pub struct ReusedCosts {
    dm: DelayModel,
    fp: u64,
}

impl ReusedCosts {
    /// Wrap `inner` so every block's execution cost counts `reuse` times.
    /// `reuse = 1` is the identity: same delay model, same fingerprint,
    /// so batch-1 decode plans share cache entries with the plain path.
    pub fn new(inner: &dyn CostProvider, reuse: usize) -> ReusedCosts {
        let base = inner.delay_model();
        if reuse <= 1 {
            return ReusedCosts { dm: base.clone(), fp: inner.fingerprint() };
        }
        let k = reuse as f64;
        let dm = DelayModel {
            gamma_cpu_s_per_flop: base.gamma_cpu_s_per_flop * k,
            gamma_gpu_s_per_flop: base.gamma_gpu_s_per_flop * k,
            dispatch_s_per_block: base.dispatch_s_per_block * k,
            ..base.clone()
        };
        let fp = fnv1a([inner.fingerprint(), reuse as u64]);
        ReusedCosts { dm, fp }
    }
}

impl CostProvider for ReusedCosts {
    fn name(&self) -> &'static str {
        "reused"
    }

    fn delay_model(&self) -> &DelayModel {
        &self.dm
    }

    fn fingerprint(&self) -> u64 {
        self.fp
    }
}

/// Owned provider storage for planners (concrete, so the measured
/// variant stays mutable for online refinement without downcasting).
#[derive(Debug, Clone)]
pub enum Costs {
    Analytic(AnalyticCosts),
    Measured(MeasuredCosts),
}

impl Costs {
    pub fn provider(&self) -> &dyn CostProvider {
        match self {
            Costs::Analytic(a) => a,
            Costs::Measured(m) => m,
        }
    }

    /// Fold an observation into measured costs (no-op for analytic).
    /// Returns true when the fingerprint moved.
    pub fn observe(&mut self, obs: &CostObservation) -> bool {
        match self {
            Costs::Analytic(_) => false,
            Costs::Measured(m) => m.observe(obs),
        }
    }

    /// Fold a decompress measurement into measured costs (no-op for
    /// analytic). Returns true when the fingerprint moved.
    pub fn observe_decompress(&mut self, bytes: u64, seen_s: f64) -> bool {
        match self {
            Costs::Analytic(_) => false,
            Costs::Measured(m) => m.observe_decompress(bytes, seen_s),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MB;
    use crate::delay::profiler;

    fn block(size_mb: u64, depth: u32, gflops: f64) -> BlockInfo {
        BlockInfo {
            index: 0,
            layer_lo: 0,
            layer_hi: 1,
            size_bytes: size_mb * MB,
            depth,
            flops: (gflops * 1e9) as u64,
        }
    }

    #[test]
    fn analytic_matches_delay_model_bitwise() {
        let prof = DeviceProfile::jetson_nx();
        let dm = DelayModel::from_profile(&prof);
        let costs = AnalyticCosts::from_profile(&prof);
        let b = block(50, 40, 8.0);
        let t = costs.block_times(&b, Processor::Cpu);
        assert_eq!(t.t_in, dm.t_in(&b));
        assert_eq!(t.t_ex, dm.t_ex(&b, Processor::Cpu));
        assert_eq!(t.t_out, dm.t_out(&b));
        // Same coefficients -> same fingerprint; different -> different.
        assert_eq!(costs.fingerprint(), AnalyticCosts::new(dm.clone()).fingerprint());
        let nano = AnalyticCosts::from_profile(&DeviceProfile::jetson_nano());
        assert_ne!(costs.fingerprint(), nano.fingerprint());
    }

    #[test]
    fn measured_seeds_from_fit_and_differs_from_analytic_fp() {
        let prof = DeviceProfile::jetson_nx();
        let fit = profiler::fit(&profiler::measure_sweep(&prof, 100, 0.0, 1));
        let mc = MeasuredCosts::from_fit(&fit, &prof);
        assert_eq!(mc.scales(), (1.0, 1.0, 1.0));
        // A noiseless fit tracks the analytic model closely.
        let dm = DelayModel::from_profile(&prof);
        let b = block(80, 60, 12.0);
        let rel = (mc.delay_model().t_ex(&b, Processor::Cpu) - dm.t_ex(&b, Processor::Cpu)).abs()
            / dm.t_ex(&b, Processor::Cpu);
        assert!(rel < 0.05, "{rel}");
    }

    #[test]
    fn observations_drift_scales_and_fingerprint() {
        let prof = DeviceProfile::jetson_nx();
        let fit = profiler::fit(&profiler::measure_sweep(&prof, 100, 0.0, 1));
        let mut mc = MeasuredCosts::from_fit(&fit, &prof);
        let fp0 = mc.fingerprint();
        let b = block(100, 80, 15.0);
        // The "device" consistently swaps 2x slower than fitted.
        let obs = CostObservation {
            n_blocks: 3,
            bytes: b.size_bytes,
            depth: b.depth,
            flops: b.flops,
            proc: Processor::Cpu,
            swap_s: 2.0 * (mc.delay_model().alpha_s_per_byte * b.size_bytes as f64
                + mc.delay_model().dma_setup_s * 3.0),
            assembly_s: mc.delay_model().beta_s_per_depth * b.depth as f64,
            compute_s: mc.delay_model().gamma_cpu_s_per_flop * b.flops as f64
                + mc.delay_model().dispatch_s_per_block * 3.0,
        };
        let mut changed = false;
        for _ in 0..8 {
            changed |= mc.observe(&obs);
        }
        assert!(changed, "2x swap drift must move the fingerprint");
        assert_ne!(mc.fingerprint(), fp0);
        let (si, sa, se) = mc.scales();
        assert!(si > 1.5, "swap scale drifts up: {si}");
        assert!((sa - 1.0).abs() < 0.05, "assembly stays: {sa}");
        assert!((se - 1.0).abs() < 0.05, "compute stays: {se}");
        assert_eq!(mc.observations(), 8);
    }

    #[test]
    fn tiny_drift_keeps_the_fingerprint_stable() {
        let prof = DeviceProfile::jetson_nx();
        let fit = profiler::fit(&profiler::measure_sweep(&prof, 100, 0.0, 1));
        let mut mc = MeasuredCosts::from_fit(&fit, &prof);
        let fp0 = mc.fingerprint();
        let b = block(100, 80, 15.0);
        // 0.2% off-prediction: inside the quantization bucket.
        let obs = CostObservation {
            n_blocks: 2,
            bytes: b.size_bytes,
            depth: b.depth,
            flops: b.flops,
            proc: Processor::Cpu,
            swap_s: 1.002
                * (mc.delay_model().alpha_s_per_byte * b.size_bytes as f64
                    + mc.delay_model().dma_setup_s * 2.0),
            assembly_s: 1.002 * mc.delay_model().beta_s_per_depth * b.depth as f64,
            compute_s: 1.002
                * (mc.delay_model().gamma_cpu_s_per_flop * b.flops as f64
                    + mc.delay_model().dispatch_s_per_block * 2.0),
        };
        assert!(!mc.observe(&obs), "sub-bucket drift must not invalidate");
        assert_eq!(mc.fingerprint(), fp0);
    }

    #[test]
    fn model_fingerprint_tracks_chain_content() {
        let a = crate::model::families::resnet101();
        let mut b = crate::model::families::resnet101();
        assert_eq!(model_fingerprint(&a), model_fingerprint(&b));
        // Same name, different chain -> different fingerprint.
        b.layers[0].size_bytes += 1;
        assert_ne!(model_fingerprint(&a), model_fingerprint(&b));
        let mut c = crate::model::families::resnet101();
        c.layers[3].cut_after = false;
        assert_ne!(model_fingerprint(&a), model_fingerprint(&c));
    }

    #[test]
    fn reused_costs_scale_exactly_t_ex() {
        let prof = DeviceProfile::jetson_nx();
        let inner = AnalyticCosts::from_profile(&prof);
        let b = block(60, 30, 9.0);
        for reuse in [2usize, 4, 16] {
            let rc = ReusedCosts::new(&inner, reuse);
            for proc in [Processor::Cpu, Processor::Gpu] {
                let base = inner.block_times(&b, proc);
                let t = rc.block_times(&b, proc);
                assert_eq!(t.t_in, base.t_in, "swap-in paid once");
                assert_eq!(t.t_out, base.t_out, "swap-out paid once");
                assert!(
                    (t.t_ex - base.t_ex * reuse as f64).abs() < 1e-12 * t.t_ex,
                    "t_ex must scale by the batch width"
                );
            }
            assert_ne!(rc.fingerprint(), inner.fingerprint());
        }
        // Distinct widths key distinct plans; width 1 is the identity.
        assert_ne!(
            ReusedCosts::new(&inner, 2).fingerprint(),
            ReusedCosts::new(&inner, 4).fingerprint()
        );
        let id = ReusedCosts::new(&inner, 1);
        assert_eq!(id.fingerprint(), inner.fingerprint());
        let t = id.block_times(&b, Processor::Gpu);
        let base = inner.block_times(&b, Processor::Gpu);
        assert_eq!(t.t_ex, base.t_ex);
    }

    #[test]
    fn variant_times_follow_the_device_tradeoff() {
        use crate::pipeline::SwapVariant;
        let b = block(100, 40, 2.0); // IO-bound: 100 MB, 2 GFLOPs
        let nx = AnalyticCosts::from_profile(&DeviceProfile::jetson_nx());
        let nano = AnalyticCosts::from_profile(&DeviceProfile::jetson_nano());
        for costs in [&nx, &nano] {
            let plain = costs.variant_times(&b, Processor::Gpu, SwapVariant::Plain);
            assert_eq!(plain, costs.block_times(&b, Processor::Gpu), "Plain is the base path");
        }
        // NX Carmel decompresses faster than the saved IO; Nano doesn't.
        let nx_plain = nx.variant_times(&b, Processor::Gpu, SwapVariant::Plain);
        let nx_lz = nx.variant_times(&b, Processor::Gpu, SwapVariant::Compressed);
        assert!(nx_lz.t_in < nx_plain.t_in, "{} !< {}", nx_lz.t_in, nx_plain.t_in);
        let nano_plain = nano.variant_times(&b, Processor::Gpu, SwapVariant::Plain);
        let nano_lz = nano.variant_times(&b, Processor::Gpu, SwapVariant::Compressed);
        assert!(nano_lz.t_in > nano_plain.t_in, "{} !> {}", nano_lz.t_in, nano_plain.t_in);
        // Tiling is strictly slower on both axes but halves the peak.
        let tiled = nx.variant_times(&b, Processor::Gpu, SwapVariant::Tiled { t: 4 });
        assert!(tiled.t_in > nx_plain.t_in && tiled.t_ex > nx_plain.t_ex);
        assert_eq!(tiled.t_out, nx_plain.t_out);
        assert_eq!(SwapVariant::Tiled { t: 4 }.working_set(b.size_bytes), b.size_bytes / 2);
        assert_eq!(SwapVariant::Compressed.working_set(b.size_bytes), b.size_bytes);
    }

    #[test]
    fn decompress_drift_moves_the_fingerprint() {
        let prof = DeviceProfile::jetson_nx();
        let fit = profiler::fit(&profiler::measure_sweep(&prof, 100, 0.0, 1));
        let mut mc = MeasuredCosts::from_fit(&fit, &prof);
        let fp0 = mc.fingerprint();
        let bytes = 100 * MB;
        // Sub-bucket drift (0.2% slow) stays inside the quantization band.
        let pred = mc.delay_model().decompress_s_per_byte * bytes as f64;
        assert!(!mc.observe_decompress(bytes, pred * 1.002), "sub-bucket must hold");
        assert_eq!(mc.fingerprint(), fp0);
        // A consistent 2x-slow decompressor must invalidate.
        let mut changed = false;
        for _ in 0..8 {
            changed |= mc.observe_decompress(bytes, pred * 2.0);
        }
        assert!(changed, "2x decompress drift must move the fingerprint");
        assert_ne!(mc.fingerprint(), fp0);
        assert!(
            mc.delay_model().decompress_s_per_byte > mc.delay_model().alpha_s_per_byte * 0.5,
            "after drift the NX codec win is gone"
        );
    }

    #[test]
    fn costs_enum_routes_observations() {
        let prof = DeviceProfile::jetson_nx();
        let mut a = Costs::Analytic(AnalyticCosts::from_profile(&prof));
        let obs = CostObservation {
            n_blocks: 1,
            bytes: MB,
            depth: 4,
            flops: 1_000_000,
            proc: Processor::Cpu,
            swap_s: 1.0,
            assembly_s: 1.0,
            compute_s: 1.0,
        };
        assert!(!a.observe(&obs), "analytic ignores observations");
        assert_eq!(a.provider().name(), "analytic");
    }
}
