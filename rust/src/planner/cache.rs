//! The shared plan cache: (model, spec, budget band, cost fingerprint)
//! -> partition plan, plus the per-(model, n) DP frontier tables behind
//! it (the paper's "strategy lookup tables", §8.5: 0.5-3.4 MB resident).
//!
//! Re-partition events — `ModelHandle::rebudget`, `scheduler::adapt`,
//! `server::multi` register/evict storms — used to rebuild lookup
//! tables from scratch per tenant. With the cache they become probes:
//! a plan-level hit returns the cached schedule, a table-level hit
//! reuses the DP frontier and only re-prunes it by the new budget.
//! Entries are keyed by the cost provider's fingerprint, so measured
//! cost drift invalidates exactly the plans it obsoletes. Total bytes
//! are bounded (`--plan-cache-bytes`): inserts evict least-recently
//! used entries first, and an entry larger than the whole bound is
//! simply not cached.

use std::collections::HashMap;
use std::rc::Rc;

use crate::pipeline::PipelineSpec;
use crate::scheduler::partition::LookupTable;
use crate::scheduler::Schedule;

/// Budget band width for plan-level keys: budgets within one band share
/// a cached plan (planned at the lowest budget seen in the band, so the
/// plan stays feasible for every later probe in the band).
pub const DEFAULT_BAND_BYTES: u64 = 1_000_000;

/// Default cache capacity — the top of the paper's §8.5 strategy-table
/// band.
pub const DEFAULT_CACHE_BYTES: u64 = 4_000_000;

/// Pinned-bytes band width for decode plan keys. KV caches grow a few
/// hundred KB per token per sequence, so planning per exact byte count
/// would make every decode step a cache miss; planning per 64 MB band
/// (against the band ceiling, so the plan stays feasible as KV grows
/// within the band) turns growth re-plans into cache probes.
pub const DEFAULT_PINNED_BAND_BYTES: u64 = 64 * 1024 * 1024;

/// Cache sizing knobs.
#[derive(Debug, Clone, Copy)]
pub struct PlanCacheConfig {
    /// Hard byte bound across plans + tables (0 disables caching).
    pub capacity_bytes: u64,
    /// Plan-key budget quantization.
    pub band_bytes: u64,
}

impl Default for PlanCacheConfig {
    fn default() -> PlanCacheConfig {
        PlanCacheConfig {
            capacity_bytes: DEFAULT_CACHE_BYTES,
            band_bytes: DEFAULT_BAND_BYTES,
        }
    }
}

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct PlanKey {
    model: String,
    /// Chain-content fingerprint (`cost::model_fingerprint`): two
    /// models sharing a name but not a chain must never alias.
    chain: u64,
    residency_m: usize,
    swap_channels: usize,
    band: u64,
    /// Pinned-bytes band (KV-cache load) the plan was made under. Two
    /// tenants with identical chains but different pinned loads must not
    /// share a schedule — the swap window they plan against differs.
    pinned_band: u64,
    /// Decode batch width (per-step reuse). 1 for ordinary inference.
    batch: usize,
    fingerprint: u64,
}

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct TableKey {
    model: String,
    chain: u64,
    residency_m: usize,
    swap_channels: usize,
    n: usize,
    fingerprint: u64,
}

#[derive(Debug, Clone)]
struct PlanEntry {
    /// The budget the plan was computed for: reusable for any probe
    /// budget >= it (feasibility is monotone in budget).
    planned_budget: u64,
    schedule: Schedule,
    bytes: u64,
    tick: u64,
}

#[derive(Debug, Clone)]
struct TableEntry {
    /// Shared, immutable frontier — probes hand out the Rc instead of
    /// deep-cloning the whole table per plan-walk step.
    table: Rc<LookupTable>,
    bytes: u64,
    tick: u64,
}

/// Cumulative cache/planner counters, snapshotted into reports.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PlanStats {
    /// Cost provider behind the plans ("analytic" | "measured").
    pub cost_source: String,
    /// Current cost fingerprint keying live entries.
    pub fingerprint: u64,
    /// Plan-level probes answered from cache.
    pub hits: u64,
    /// Plan-level probes that had to (re)plan.
    pub misses: u64,
    /// DP frontier tables reused from cache during planning.
    pub table_hits: u64,
    /// DP frontier tables built.
    pub table_misses: u64,
    /// Entries evicted to respect the byte bound.
    pub evictions: u64,
    /// Entries dropped by cost-fingerprint drift.
    pub invalidations: u64,
    /// Live entries (plans + tables).
    pub entries: u64,
    /// Resident bytes of all live entries.
    pub bytes: u64,
    /// Cumulative DP block-interval evaluations.
    pub dp_evals: u64,
    /// DP runs whose per-cell frontier hit the safety cap (optimality
    /// degraded to best-effort for those frontiers) — 0 for every
    /// in-tree model family.
    pub capped_frontiers: u64,
}

/// The shared plan/table cache (see module docs).
#[derive(Debug)]
pub struct PlanCache {
    cfg: PlanCacheConfig,
    plans: HashMap<PlanKey, PlanEntry>,
    tables: HashMap<TableKey, TableEntry>,
    bytes: u64,
    tick: u64,
    pub(crate) hits: u64,
    pub(crate) misses: u64,
    pub(crate) table_hits: u64,
    pub(crate) table_misses: u64,
    pub(crate) evictions: u64,
    pub(crate) invalidations: u64,
}

impl PlanCache {
    pub fn new(cfg: PlanCacheConfig) -> PlanCache {
        PlanCache {
            cfg: PlanCacheConfig { band_bytes: cfg.band_bytes.max(1), ..cfg },
            plans: HashMap::new(),
            tables: HashMap::new(),
            bytes: 0,
            tick: 0,
            hits: 0,
            misses: 0,
            table_hits: 0,
            table_misses: 0,
            evictions: 0,
            invalidations: 0,
        }
    }

    pub fn config(&self) -> PlanCacheConfig {
        self.cfg
    }

    /// Resident bytes across all live entries.
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    pub fn entries(&self) -> u64 {
        (self.plans.len() + self.tables.len()) as u64
    }

    fn bump(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    #[allow(clippy::too_many_arguments)]
    fn plan_key(
        &self,
        model: &str,
        chain: u64,
        spec: &PipelineSpec,
        budget: u64,
        fp: u64,
        pinned_band: u64,
        batch: usize,
    ) -> PlanKey {
        PlanKey {
            model: model.to_string(),
            chain,
            residency_m: spec.residency_m,
            swap_channels: spec.swap_channels,
            band: budget / self.cfg.band_bytes,
            pinned_band,
            batch,
            fingerprint: fp,
        }
    }

    /// Probe for a cached plan serving `budget`. A hit requires the
    /// entry's planned budget to be <= the probe's (a plan for less
    /// memory always fits more); the returned schedule is restamped to
    /// the probe budget.
    #[allow(clippy::too_many_arguments)]
    pub fn get_plan(
        &mut self,
        model: &str,
        chain: u64,
        spec: &PipelineSpec,
        budget: u64,
        fp: u64,
    ) -> Option<Schedule> {
        self.get_plan_at(model, chain, spec, budget, fp, 0, 1)
    }

    /// [`Self::get_plan`] with the decode dimensions explicit: the
    /// pinned-bytes band the swap window was reduced by and the decode
    /// batch width. Ordinary inference probes use (0, 1).
    #[allow(clippy::too_many_arguments)]
    pub fn get_plan_at(
        &mut self,
        model: &str,
        chain: u64,
        spec: &PipelineSpec,
        budget: u64,
        fp: u64,
        pinned_band: u64,
        batch: usize,
    ) -> Option<Schedule> {
        let key = self.plan_key(model, chain, spec, budget, fp, pinned_band, batch);
        let tick = self.bump();
        match self.plans.get_mut(&key) {
            Some(e) if e.planned_budget <= budget => {
                e.tick = tick;
                self.hits += 1;
                let mut s = e.schedule.clone();
                s.budget_bytes = budget;
                Some(s)
            }
            _ => {
                self.misses += 1;
                None
            }
        }
    }

    /// Store a freshly planned schedule. Replaces any same-band entry
    /// (the lower planned budget wins band-wide reuse).
    #[allow(clippy::too_many_arguments)]
    pub fn put_plan(
        &mut self,
        model: &str,
        chain: u64,
        spec: &PipelineSpec,
        budget: u64,
        fp: u64,
        s: &Schedule,
    ) {
        self.put_plan_at(model, chain, spec, budget, fp, 0, 1, s);
    }

    /// [`Self::put_plan`] with the decode dimensions explicit.
    #[allow(clippy::too_many_arguments)]
    pub fn put_plan_at(
        &mut self,
        model: &str,
        chain: u64,
        spec: &PipelineSpec,
        budget: u64,
        fp: u64,
        pinned_band: u64,
        batch: usize,
        s: &Schedule,
    ) {
        let key = self.plan_key(model, chain, spec, budget, fp, pinned_band, batch);
        let bytes = plan_bytes(s);
        let tick = self.bump();
        if let Some(old) = self.plans.remove(&key) {
            self.bytes -= old.bytes;
        }
        if !self.make_room(bytes) {
            return;
        }
        self.bytes += bytes;
        self.plans.insert(
            key,
            PlanEntry { planned_budget: budget, schedule: s.clone(), bytes, tick },
        );
    }

    /// Probe for a cached DP frontier table.
    pub fn get_table(
        &mut self,
        model: &str,
        chain: u64,
        spec: &PipelineSpec,
        n: usize,
        fp: u64,
    ) -> Option<Rc<LookupTable>> {
        let key = TableKey {
            model: model.to_string(),
            chain,
            residency_m: spec.residency_m,
            swap_channels: spec.swap_channels,
            n,
            fingerprint: fp,
        };
        let tick = self.bump();
        match self.tables.get_mut(&key) {
            Some(e) => {
                e.tick = tick;
                self.table_hits += 1;
                Some(e.table.clone())
            }
            None => {
                self.table_misses += 1;
                None
            }
        }
    }

    /// Store a DP frontier table.
    #[allow(clippy::too_many_arguments)]
    pub fn put_table(
        &mut self,
        model: &str,
        chain: u64,
        spec: &PipelineSpec,
        n: usize,
        fp: u64,
        t: &Rc<LookupTable>,
    ) {
        let key = TableKey {
            model: model.to_string(),
            chain,
            residency_m: spec.residency_m,
            swap_channels: spec.swap_channels,
            n,
            fingerprint: fp,
        };
        let bytes = t.approx_bytes();
        let tick = self.bump();
        if let Some(old) = self.tables.remove(&key) {
            self.bytes -= old.bytes;
        }
        if !self.make_room(bytes) {
            return;
        }
        self.bytes += bytes;
        self.tables.insert(key, TableEntry { table: t.clone(), bytes, tick });
    }

    /// Evict LRU entries until `incoming` bytes fit under the bound.
    /// Returns false when the incoming entry alone exceeds the bound
    /// (it is then not cached at all).
    fn make_room(&mut self, incoming: u64) -> bool {
        if incoming > self.cfg.capacity_bytes {
            return false;
        }
        while self.bytes + incoming > self.cfg.capacity_bytes {
            let plan_lru = self.plans.iter().min_by_key(|(_, e)| e.tick).map(|(k, e)| (k.clone(), e.tick));
            let table_lru =
                self.tables.iter().min_by_key(|(_, e)| e.tick).map(|(k, e)| (k.clone(), e.tick));
            match (plan_lru, table_lru) {
                (Some((pk, pt)), Some((_, tt))) if pt <= tt => {
                    let e = self.plans.remove(&pk).expect("lru plan present");
                    self.bytes -= e.bytes;
                }
                (_, Some((tk, _))) => {
                    let e = self.tables.remove(&tk).expect("lru table present");
                    self.bytes -= e.bytes;
                }
                (Some((pk, _)), None) => {
                    let e = self.plans.remove(&pk).expect("lru plan present");
                    self.bytes -= e.bytes;
                }
                (None, None) => return false,
            }
            self.evictions += 1;
        }
        true
    }

    /// Drop every entry not keyed by `fp` — cost-fingerprint drift
    /// invalidation.
    pub fn retain_fingerprint(&mut self, fp: u64) {
        let before = self.entries();
        let mut freed = 0u64;
        self.plans.retain(|k, e| {
            let keep = k.fingerprint == fp;
            if !keep {
                freed += e.bytes;
            }
            keep
        });
        self.tables.retain(|k, e| {
            let keep = k.fingerprint == fp;
            if !keep {
                freed += e.bytes;
            }
            keep
        });
        self.bytes -= freed;
        self.invalidations += before - self.entries();
    }
}

/// Resident-size estimate of one cached plan (points + fixed header),
/// mirroring `LookupTable::approx_bytes`'s accounting style.
pub fn plan_bytes(s: &Schedule) -> u64 {
    s.points.len() as u64 * 8 + s.model.len() as u64 + 64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched(model: &str, budget: u64, points: Vec<usize>) -> Schedule {
        let n_blocks = points.len() + 1;
        Schedule {
            model: model.into(),
            budget_bytes: budget,
            n_blocks,
            points,
            predicted_latency_s: 0.5,
            peak_bytes: budget / 2,
            variants: vec![crate::pipeline::SwapVariant::Plain; n_blocks],
        }
    }

    fn table(model: &str, n: usize, rows: usize) -> LookupTable {
        LookupTable {
            model: model.into(),
            n_blocks: n,
            rows: (0..rows)
                .map(|i| crate::scheduler::partition::Row {
                    points: vec![i + 1],
                    max_mem_bytes: 1000 + i as u64,
                    predicted_latency_s: 1.0 - i as f64 * 1e-3,
                    variants: vec![crate::pipeline::SwapVariant::Plain; 2],
                })
                .collect(),
        }
    }

    #[test]
    fn plan_probe_hits_same_band_and_higher_budget() {
        let mut c = PlanCache::new(PlanCacheConfig::default());
        let spec = PipelineSpec::default();
        let s = sched("m", 100_000_000, vec![3, 7]);
        assert!(c.get_plan("m", 9, &spec, 100_000_000, 1).is_none());
        c.put_plan("m", 9, &spec, 100_000_000, 1, &s);
        let hit = c.get_plan("m", 9, &spec, 100_000_000, 1).unwrap();
        assert_eq!(hit.points, s.points);
        // Higher budget in the same band reuses, restamped.
        let hit2 = c.get_plan("m", 9, &spec, 100_400_000, 1).unwrap();
        assert_eq!(hit2.budget_bytes, 100_400_000);
        // Lower budget in the band must not reuse a bigger-budget plan.
        assert!(c.get_plan("m", 9, &spec, 99_999_999, 1).is_none());
        // Other spec, band, or fingerprint: miss.
        assert!(c.get_plan("m", 9, &PipelineSpec::with_residency(3), 100_000_000, 1).is_none());
        assert!(c.get_plan("m", 9, &spec, 200_000_000, 1).is_none());
        assert!(c.get_plan("m", 9, &spec, 100_000_000, 2).is_none());
        assert_eq!(c.hits, 2);
    }

    #[test]
    fn pinned_band_and_batch_partition_the_key_space() {
        let mut c = PlanCache::new(PlanCacheConfig::default());
        let spec = PipelineSpec::default();
        let s = sched("m", 100_000_000, vec![3, 7]);
        c.put_plan_at("m", 9, &spec, 100_000_000, 1, 2, 4, &s);
        assert!(c.get_plan_at("m", 9, &spec, 100_000_000, 1, 2, 4).is_some());
        // A different pinned band or batch width is a different plan.
        assert!(c.get_plan_at("m", 9, &spec, 100_000_000, 1, 3, 4).is_none());
        assert!(c.get_plan_at("m", 9, &spec, 100_000_000, 1, 2, 8).is_none());
        // The legacy probe is exactly (pinned_band 0, batch 1).
        assert!(c.get_plan("m", 9, &spec, 100_000_000, 1).is_none());
        c.put_plan("m", 9, &spec, 100_000_000, 1, &s);
        assert!(c.get_plan_at("m", 9, &spec, 100_000_000, 1, 0, 1).is_some());
    }

    #[test]
    fn byte_bound_is_hard_and_lru_evicts() {
        let t = Rc::new(table("m", 3, 100)); // 100 * (3*8 + 16) = 4000 B
        let cap = 2 * t.approx_bytes() + 10;
        let mut c = PlanCache::new(PlanCacheConfig { capacity_bytes: cap, band_bytes: 1 });
        let spec = PipelineSpec::default();
        for n in 0..6 {
            c.put_table("m", 9, &spec, n, 1, &t);
            assert!(c.bytes() <= cap, "{} > {cap}", c.bytes());
        }
        assert_eq!(c.entries(), 2, "only two tables fit");
        assert!(c.evictions >= 4);
        // An entry bigger than the whole bound is not cached.
        let big = Rc::new(table("m", 3, 1000));
        let mut small = PlanCache::new(PlanCacheConfig { capacity_bytes: 100, band_bytes: 1 });
        small.put_table("m", 9, &spec, 3, 1, &big);
        assert_eq!(small.bytes(), 0);
        assert_eq!(small.entries(), 0);
    }

    #[test]
    fn fingerprint_drift_invalidates() {
        let mut c = PlanCache::new(PlanCacheConfig::default());
        let spec = PipelineSpec::default();
        c.put_plan("m", 9, &spec, 1_000_000, 1, &sched("m", 1_000_000, vec![2]));
        c.put_table("m", 9, &spec, 3, 1, &Rc::new(table("m", 3, 10)));
        c.put_table("m", 9, &spec, 4, 2, &Rc::new(table("m", 4, 10)));
        c.retain_fingerprint(2);
        assert_eq!(c.entries(), 1);
        assert_eq!(c.invalidations, 2);
        assert!(c.get_plan("m", 9, &spec, 1_000_000, 1).is_none());
        assert!(c.get_table("m", 9, &spec, 4, 2).is_some());
        let expected = table("m", 4, 10).approx_bytes();
        assert_eq!(c.bytes(), expected);
    }

    #[test]
    fn zero_capacity_disables_caching() {
        let mut c = PlanCache::new(PlanCacheConfig { capacity_bytes: 0, band_bytes: 1_000_000 });
        let spec = PipelineSpec::default();
        c.put_plan("m", 9, &spec, 1_000_000, 1, &sched("m", 1_000_000, vec![2]));
        assert_eq!(c.entries(), 0);
        assert!(c.get_plan("m", 9, &spec, 1_000_000, 1).is_none());
    }
}
