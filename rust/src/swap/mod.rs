//! Block swapping controller (paper §4).
//!
//! Two swap-in implementations over the same [`Storage`]/[`MemSim`]
//! substrates:
//!
//! * **Standard** (§4.1, what the stock tool chain does): buffered read
//!   through the page cache (extra resident copy #1), `malloc` a CPU
//!   tensor and copy into it, and — when the model runs on the GPU — a
//!   `.to('cuda')` dispatch that converts the tensor to GPU format and
//!   copies it into the "fake GPU memory" (extra resident copy #2, kept
//!   by the framework for the lifetime of the tensor).
//!
//! * **ZeroCopy** (§4.2, SwapNet): direct-I/O DMA fetch into ONE
//!   unified-addressing allocation (`cudaMallocManaged`); the revised GPU
//!   dispatch returns the same pointer — no conversion, no copy.
//!
//! Swap-out (§4.1) is write-back-free for both: parameters are immutable
//! during inference, so the memory is simply freed (plus skeleton pointer
//! reset + GC on the SwapNet path).

use std::path::Path;

use anyhow::Result;

use crate::config::{DeviceProfile, Processor};
use crate::hostmem::{BlockBuffer, BufferPool, PooledBuf};
use crate::memsim::{AllocId, MemSim, Space};
use crate::model::BlockInfo;
use crate::pipeline::SwapVariant;
use crate::storage::{content_file_id, Channel, ReadReport, Storage};

/// Which swap-in implementation to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SwapMode {
    /// Stock tool-chain path (baselines / w/o-uni-add ablation).
    Standard,
    /// SwapNet zero-copy path.
    ZeroCopy,
}

/// A block resident in (simulated) memory.
///
/// `data` is ONE residency type for both worlds: real file swap-ins
/// land their bytes in it (a recycled pool slot on the pooled path, a
/// detached buffer otherwise), while cost-model-only swap-ins carry the
/// empty detached buffer. Dropping a pooled `ResidentBlock` returns its
/// slot to the engine's [`BufferPool`].
#[derive(Debug)]
pub struct ResidentBlock {
    pub block: BlockInfo,
    /// Parameter bytes (empty for cost-model-only swap-ins).
    pub data: PooledBuf,
    /// True when a direct-channel read degraded to buffered I/O.
    pub direct_fallback: bool,
    /// Live simulator allocations backing this block (freed at swap-out).
    allocs: Vec<AllocId>,
    /// Simulated swap-in latency.
    pub swap_in_s: f64,
    /// Bytes that actually crossed the storage channel for this swap-in
    /// (wire bytes: less than the block size for compressed variants).
    pub io_bytes: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
}

/// Report of one swap-out.
#[derive(Debug, Clone, Copy)]
pub struct SwapOutReport {
    pub sim_latency_s: f64,
    pub freed_bytes: u64,
}

/// The block swapping controller.
pub struct SwapController {
    pub mode: SwapMode,
    pub tag: String,
}

impl SwapController {
    pub fn new(mode: SwapMode, tag: &str) -> Self {
        SwapController { mode, tag: tag.to_string() }
    }

    fn channel(&self) -> Channel {
        match self.mode {
            SwapMode::Standard => Channel::Buffered,
            SwapMode::ZeroCopy => Channel::DirectDma,
        }
    }

    /// Swap a block in from a synthetic file id (paper-scale simulation;
    /// no real bytes). `proc` decides whether the GPU dispatch path runs.
    pub fn swap_in_sim(
        &self,
        block: &BlockInfo,
        file: u64,
        proc: Processor,
        storage: &mut Storage,
        mem: &mut MemSim,
        prof: &DeviceProfile,
    ) -> ResidentBlock {
        self.swap_in_sim_variant(block, file, proc, SwapVariant::Plain, storage, mem, prof)
    }

    /// [`swap_in_sim`](Self::swap_in_sim) under a planner-chosen swap
    /// variant (DESIGN.md §13). The IO and residency consequences follow
    /// the variant's cost law exactly:
    ///
    /// * `Compressed` — wire bytes at the planner's provisioning ratio
    ///   cross the channel, then the CPU decompressor streams over the
    ///   full payload; the resident copy is the decompressed block.
    /// * `Tiled { t }` — the same payload bytes cross in `t`
    ///   sub-transfers (extra DMA setups, or cache-management passes on
    ///   the buffered channel), and only the tile working set is ever
    ///   resident at once — the memory ledger is charged for that, not
    ///   the full block.
    #[allow(clippy::too_many_arguments)]
    pub fn swap_in_sim_variant(
        &self,
        block: &BlockInfo,
        file: u64,
        proc: Processor,
        variant: SwapVariant,
        storage: &mut Storage,
        mem: &mut MemSim,
        prof: &DeviceProfile,
    ) -> ResidentBlock {
        let io = match variant {
            SwapVariant::Plain => {
                storage.read_sim(file, block.size_bytes, self.channel(), mem, prof)
            }
            SwapVariant::Compressed => {
                let wire = (block.size_bytes as f64 * crate::codec::PLANNED_RATIO).ceil() as u64;
                let mut r = storage.read_sim(file, wire, self.channel(), mem, prof);
                r.sim_latency_s += prof.decompress_s_per_byte * block.size_bytes as f64;
                r
            }
            SwapVariant::Tiled { t } => {
                let mut r = storage.read_sim(file, block.size_bytes, self.channel(), mem, prof);
                let extra = t.saturating_sub(1) as f64;
                r.sim_latency_s += match self.channel() {
                    Channel::DirectDma => storage.dma_setup_s * extra,
                    Channel::Buffered => prof.cache_mgmt_s * extra,
                };
                r
            }
        };
        let resident = variant.working_set(block.size_bytes);
        let (report, allocs) = self.dispatch_and_copy(block, proc, resident, mem, prof, io);
        ResidentBlock {
            block: block.clone(),
            data: PooledBuf::detached(BlockBuffer::empty()),
            direct_fallback: false,
            allocs,
            swap_in_s: report.sim_latency_s,
            io_bytes: report.bytes,
            cache_hits: report.cache_hits,
            cache_misses: report.cache_misses,
        }
    }

    /// Swap a block in by content hash (the dedup store's hash-keyed
    /// read path): resolves the hash to its content-addressed file id,
    /// so two tenants whose blocks share a hash read the same synthetic
    /// file — and, on the buffered channel, the same page-cache entry.
    pub fn swap_in_content(
        &self,
        block: &BlockInfo,
        hash: u64,
        proc: Processor,
        storage: &mut Storage,
        mem: &mut MemSim,
        prof: &DeviceProfile,
    ) -> ResidentBlock {
        self.swap_in_sim(block, content_file_id(hash), proc, storage, mem, prof)
    }

    /// Content-hash swap-in under a planner-chosen variant: the file id
    /// is resolved through the codec-tagged namespace
    /// ([`crate::blockstore::variant_file_id`]), so compressed reads
    /// share pages with other tenants that chose Compressed — and never
    /// alias the plain file.
    #[allow(clippy::too_many_arguments)]
    pub fn swap_in_content_variant(
        &self,
        block: &BlockInfo,
        hash: u64,
        proc: Processor,
        variant: SwapVariant,
        storage: &mut Storage,
        mem: &mut MemSim,
        prof: &DeviceProfile,
    ) -> ResidentBlock {
        let file = crate::blockstore::variant_file_id(hash, variant);
        self.swap_in_sim_variant(block, file, proc, variant, storage, mem, prof)
    }

    /// Swap a block in from a real parameter file (artifact execution):
    /// really reads the bytes into a fresh detached buffer, and applies
    /// the same cost model.
    pub fn swap_in_file(
        &self,
        block: &BlockInfo,
        path: &Path,
        proc: Processor,
        storage: &mut Storage,
        mem: &mut MemSim,
        prof: &DeviceProfile,
    ) -> Result<ResidentBlock> {
        let buf = PooledBuf::detached(BlockBuffer::empty());
        self.swap_in_file_buf(block, path, proc, storage, mem, prof, buf)
    }

    /// [`swap_in_file`](Self::swap_in_file) landing the bytes in a slot
    /// checked out of `pool` — the recycled, allocation-free steady
    /// state. The slot returns to the pool when the `ResidentBlock` is
    /// dropped (swap-out).
    #[allow(clippy::too_many_arguments)]
    pub fn swap_in_file_pooled(
        &self,
        block: &BlockInfo,
        path: &Path,
        proc: Processor,
        storage: &mut Storage,
        mem: &mut MemSim,
        prof: &DeviceProfile,
        pool: &BufferPool,
    ) -> Result<ResidentBlock> {
        self.swap_in_file_buf(block, path, proc, storage, mem, prof, pool.checkout())
    }

    /// Swap a block in from a codec-compressed parameter file: the wire
    /// bytes land in a scratch region of the checked-out slot and are
    /// decompressed in place in front of it
    /// ([`Storage::read_compressed_into`]) — one slot, no second buffer,
    /// zero heap allocations once the slot is warm. The resident payload
    /// is bitwise-identical to what the plain path reads.
    #[allow(clippy::too_many_arguments)]
    pub fn swap_in_file_compressed(
        &self,
        block: &BlockInfo,
        path: &Path,
        proc: Processor,
        storage: &mut Storage,
        mem: &mut MemSim,
        prof: &DeviceProfile,
        pool: &BufferPool,
    ) -> Result<ResidentBlock> {
        let mut buf = pool.checkout();
        let io = storage.read_compressed_into(
            path,
            self.channel(),
            block.size_bytes as usize,
            &mut buf,
            mem,
            prof,
        )?;
        let fallback = io.direct_fallback;
        let (report, allocs) = self.dispatch_and_copy(block, proc, block.size_bytes, mem, prof, io);
        Ok(ResidentBlock {
            block: block.clone(),
            data: buf,
            direct_fallback: fallback,
            allocs,
            swap_in_s: report.sim_latency_s,
            io_bytes: report.bytes,
            cache_hits: report.cache_hits,
            cache_misses: report.cache_misses,
        })
    }

    #[allow(clippy::too_many_arguments)]
    fn swap_in_file_buf(
        &self,
        block: &BlockInfo,
        path: &Path,
        proc: Processor,
        storage: &mut Storage,
        mem: &mut MemSim,
        prof: &DeviceProfile,
        mut buf: PooledBuf,
    ) -> Result<ResidentBlock> {
        let io = storage.read_into(path, self.channel(), &mut buf, mem, prof)?;
        let fallback = io.direct_fallback;
        let (report, allocs) = self.dispatch_and_copy(block, proc, block.size_bytes, mem, prof, io);
        Ok(ResidentBlock {
            block: block.clone(),
            data: buf,
            direct_fallback: fallback,
            allocs,
            swap_in_s: report.sim_latency_s,
            io_bytes: report.bytes,
            cache_hits: report.cache_hits,
            cache_misses: report.cache_misses,
        })
    }

    /// The post-I/O part of swap-in: tensor allocation + GPU dispatch.
    /// `resident_bytes` is what the memory ledger is charged — the full
    /// block for plain/compressed variants, the tile working set for
    /// tiled ones. Copy/convert costs always cover the full payload
    /// (every byte passes through), and the report keeps `io.bytes`:
    /// the wire bytes that actually crossed the channel.
    fn dispatch_and_copy(
        &self,
        block: &BlockInfo,
        proc: Processor,
        resident_bytes: u64,
        mem: &mut MemSim,
        prof: &DeviceProfile,
        io: ReadReport,
    ) -> (ReadReport, Vec<AllocId>) {
        let mut lat = io.sim_latency_s;
        let mut allocs = Vec::new();
        match self.mode {
            SwapMode::Standard => {
                // CPU tensor: malloc + copy from the page cache / read buf.
                let cpu = mem.alloc(&self.tag, Space::Cpu, resident_bytes);
                allocs.push(cpu);
                lat += block.size_bytes as f64 * prof.memcpy_s_per_byte;
                if proc == Processor::Gpu {
                    // .to('cuda'): allocate fake-GPU region, convert+copy.
                    // The stock framework keeps BOTH copies live (the CPU
                    // tensor stays referenced) — the paper's "two
                    // unnecessary copies co-existing in the same physical
                    // system memory".
                    let gpu = mem.alloc(&self.tag, Space::Gpu, resident_bytes);
                    allocs.push(gpu);
                    lat += prof.gpu_dispatch_s
                        + block.size_bytes as f64 * prof.gpu_convert_s_per_byte;
                }
            }
            SwapMode::ZeroCopy => {
                // One unified allocation; dispatch returns the pointer.
                let uni = mem.alloc(&self.tag, Space::Unified, resident_bytes);
                allocs.push(uni);
                if proc == Processor::Gpu {
                    // Revised dispatch (Fig 6): cudaDeviceSynchronize only.
                    lat += 120e-6;
                }
            }
        }
        (
            ReadReport {
                bytes: io.bytes,
                sim_latency_s: lat,
                cache_hits: io.cache_hits,
                cache_misses: io.cache_misses,
                direct_fallback: io.direct_fallback,
            },
            allocs,
        )
    }

    /// Reserve `bytes` of block residency for this controller's model in
    /// the shared budget ledger — the multi-tenant server acquires a
    /// model's scheduled peak (plus delta overhead) for the duration of a
    /// batch's resident window and releases it at completion, so the
    /// ledger's peak/OOM counters prove the fleet never exceeds the
    /// total budget.
    pub fn acquire_residency(&self, mem: &mut MemSim, bytes: u64) -> AllocId {
        mem.alloc(&self.tag, Space::Unified, bytes)
    }

    /// Release a residency reservation; returns the bytes freed.
    pub fn release_residency(&self, mem: &mut MemSim, id: AllocId) -> u64 {
        mem.must_free(id)
    }

    /// Eviction hygiene: drop every cached page of the model's block
    /// files (the posix_fadvise(DONTNEED) pass a real eviction issues so
    /// a departed tenant leaves no page-cache residue behind). The model
    /// reacquires its pages lazily on the next swap-in.
    pub fn evict_files(
        &self,
        files: impl IntoIterator<Item = u64>,
        storage: &mut Storage,
        mem: &mut MemSim,
    ) {
        for f in files {
            storage.evict_file_id(f, mem);
        }
    }

    /// Swap-out: free the block's allocations (write-back-free); latency
    /// is skeleton pointer reset (eta * depth) + the GC pass.
    pub fn swap_out(
        &self,
        rb: ResidentBlock,
        mem: &mut MemSim,
        prof: &DeviceProfile,
    ) -> SwapOutReport {
        let mut freed = 0;
        for id in &rb.allocs {
            freed += mem.must_free(*id);
        }
        SwapOutReport {
            sim_latency_s: prof.gc_s + prof.eta_s_per_depth * rb.block.depth as f64,
            freed_bytes: freed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MB;

    fn block(size_mb: u64) -> BlockInfo {
        BlockInfo {
            index: 0,
            layer_lo: 0,
            layer_hi: 3,
            size_bytes: size_mb * MB,
            depth: 12,
            flops: 1_000_000,
        }
    }

    fn setup() -> (Storage, MemSim, DeviceProfile) {
        (
            Storage::new(512 * MB),
            MemSim::new(8_000 * MB),
            DeviceProfile::jetson_nx(),
        )
    }

    #[test]
    fn standard_gpu_swapin_keeps_three_copies() {
        let (mut st, mut mem, prof) = setup();
        let ctl = SwapController::new(SwapMode::Standard, "yolo");
        let _rb = ctl.swap_in_sim(&block(100), 1, Processor::Gpu, &mut st, &mut mem, &prof);
        // page cache copy + CPU tensor + fake-GPU copy ~ 3x block size
        assert!(
            mem.current() >= 3 * 100 * MB - MB,
            "expected ~3x resident, got {} MB",
            mem.current() / MB
        );
        assert_eq!(mem.current_in(Space::Gpu), 100 * MB);
        assert!(mem.current_in(Space::PageCache) > 90 * MB);
    }

    #[test]
    fn standard_cpu_swapin_keeps_two_copies() {
        let (mut st, mut mem, prof) = setup();
        let ctl = SwapController::new(SwapMode::Standard, "vgg");
        let _rb = ctl.swap_in_sim(&block(100), 1, Processor::Cpu, &mut st, &mut mem, &prof);
        let cur = mem.current();
        assert!(
            (2 * 100 * MB - 2 * MB..=2 * 100 * MB + 2 * MB).contains(&cur),
            "expected ~2x resident, got {} MB",
            cur / MB
        );
    }

    #[test]
    fn zero_copy_swapin_is_single_copy() {
        let (mut st, mut mem, prof) = setup();
        let ctl = SwapController::new(SwapMode::ZeroCopy, "yolo");
        let _rb = ctl.swap_in_sim(&block(100), 1, Processor::Gpu, &mut st, &mut mem, &prof);
        assert_eq!(mem.current(), 100 * MB);
        assert_eq!(mem.current_in(Space::Unified), 100 * MB);
        assert_eq!(mem.current_in(Space::PageCache), 0);
    }

    #[test]
    fn zero_copy_much_faster_for_gpu() {
        let (mut st, mut mem, prof) = setup();
        let std_ctl = SwapController::new(SwapMode::Standard, "a");
        let zc_ctl = SwapController::new(SwapMode::ZeroCopy, "b");
        let rb_std = std_ctl.swap_in_sim(&block(100), 1, Processor::Gpu, &mut st, &mut mem, &prof);
        let rb_zc = zc_ctl.swap_in_sim(&block(100), 2, Processor::Gpu, &mut st, &mut mem, &prof);
        assert!(
            rb_std.swap_in_s > 2.0 * rb_zc.swap_in_s,
            "std {} vs zc {}",
            rb_std.swap_in_s,
            rb_zc.swap_in_s
        );
    }

    #[test]
    fn gpu_dispatch_near_cpu_cost_in_zero_copy() {
        // Paper §4.2.2: with the revised dispatch, GPU swap-in is almost
        // as cheap as CPU swap-in.
        let (mut st, mut mem, prof) = setup();
        let ctl = SwapController::new(SwapMode::ZeroCopy, "m");
        let gpu = ctl.swap_in_sim(&block(80), 1, Processor::Gpu, &mut st, &mut mem, &prof);
        let cpu = ctl.swap_in_sim(&block(80), 2, Processor::Cpu, &mut st, &mut mem, &prof);
        assert!((gpu.swap_in_s - cpu.swap_in_s).abs() < 1e-3);
    }

    #[test]
    fn content_keyed_swap_ins_share_pages_across_tenants() {
        // Two controllers (two tenants), one content hash: the second
        // buffered swap-in runs warm off the first one's cached pages.
        let (mut st, mut mem, prof) = setup();
        let a = SwapController::new(SwapMode::Standard, "a");
        let b = SwapController::new(SwapMode::Standard, "b");
        let cold = a.swap_in_content(&block(16), 0xfeed, Processor::Cpu, &mut st, &mut mem, &prof);
        assert!(cold.cache_misses > 0);
        let warm = b.swap_in_content(&block(16), 0xfeed, Processor::Cpu, &mut st, &mut mem, &prof);
        assert_eq!(warm.cache_misses, 0, "same content hash, same pages");
        assert!(warm.swap_in_s < cold.swap_in_s);
    }

    #[test]
    fn compressed_variant_moves_fewer_bytes_and_pays_cpu() {
        let (mut st, mut mem, prof) = setup();
        let ctl = SwapController::new(SwapMode::ZeroCopy, "m");
        let plain = ctl.swap_in_sim(&block(100), 1, Processor::Cpu, &mut st, &mut mem, &prof);
        let lz = ctl.swap_in_sim_variant(
            &block(100),
            2,
            Processor::Cpu,
            SwapVariant::Compressed,
            &mut st,
            &mut mem,
            &prof,
        );
        assert_eq!(plain.io_bytes, 100 * MB);
        assert_eq!(lz.io_bytes, 50 * MB, "wire bytes at the planned ratio");
        // On the NX the decompress rate beats the IO it saves.
        assert!(lz.swap_in_s < plain.swap_in_s, "{} vs {}", lz.swap_in_s, plain.swap_in_s);
        // The resident copy is still the full decompressed block.
        let out = ctl.swap_out(lz, &mut mem, &prof);
        assert_eq!(out.freed_bytes, 100 * MB);
    }

    #[test]
    fn tiled_variant_charges_the_tile_working_set() {
        let (mut st, mut mem, prof) = setup();
        let ctl = SwapController::new(SwapMode::ZeroCopy, "m");
        let v = SwapVariant::Tiled { t: 4 };
        let ws = v.working_set(100 * MB);
        assert!(ws < 100 * MB);
        let rb =
            ctl.swap_in_sim_variant(&block(100), 1, Processor::Cpu, v, &mut st, &mut mem, &prof);
        assert_eq!(mem.current(), ws, "only the tile working set is resident");
        assert_eq!(rb.io_bytes, 100 * MB, "every payload byte still crosses the wire");
        // t-1 extra DMA setups over the plain transfer.
        let plain = ctl.swap_in_sim(&block(100), 2, Processor::Cpu, &mut st, &mut mem, &prof);
        assert!(
            (rb.swap_in_s - plain.swap_in_s - 3.0 * st.dma_setup_s).abs() < 1e-9,
            "{} vs {}",
            rb.swap_in_s,
            plain.swap_in_s
        );
        let out = ctl.swap_out(rb, &mut mem, &prof);
        assert_eq!(out.freed_bytes, ws, "freed exactly what was charged");
    }

    #[test]
    fn compressed_content_ids_never_alias_plain_pages() {
        let (mut st, mut mem, prof) = setup();
        let ctl = SwapController::new(SwapMode::Standard, "a");
        let plain =
            ctl.swap_in_content(&block(16), 0xfeed, Processor::Cpu, &mut st, &mut mem, &prof);
        assert!(plain.cache_misses > 0);
        // Same content hash under the Compressed variant: a different
        // (codec-tagged) file, so its pages start cold.
        let lz = ctl.swap_in_content_variant(
            &block(16),
            0xfeed,
            Processor::Cpu,
            SwapVariant::Compressed,
            &mut st,
            &mut mem,
            &prof,
        );
        assert!(lz.cache_misses > 0, "codec namespace must not alias plain pages");
        // But it dedups with itself: a second compressed reader is warm.
        let warm = ctl.swap_in_content_variant(
            &block(16),
            0xfeed,
            Processor::Cpu,
            SwapVariant::Compressed,
            &mut st,
            &mut mem,
            &prof,
        );
        assert_eq!(warm.cache_misses, 0);
    }

    #[test]
    fn compressed_file_swap_in_lands_identical_bytes() {
        use crate::hostmem::aligned_len;
        let dir = std::env::temp_dir().join(format!("swapnet-swap-lz-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let plain_path = dir.join("b.bin");
        let lz_path = dir.join("b.lz");
        // Structured (quantized-weight-like) payload: compressible.
        let bytes: Vec<u8> = (0..1usize << 20).map(|i| ((i / 5) % 31) as u8).collect();
        std::fs::write(&plain_path, &bytes).unwrap();
        let clen = crate::storage::write_compressed_file(&lz_path, &bytes).unwrap();
        assert!(clen < bytes.len() as u64 / 2);
        let (mut st, mut mem, prof) = setup();
        let ctl = SwapController::new(SwapMode::ZeroCopy, "m");
        let mut b = block(1);
        b.size_bytes = bytes.len() as u64;
        let pool =
            BufferPool::new(aligned_len(bytes.len()) + aligned_len(clen as usize), 2);
        let plain = ctl
            .swap_in_file_pooled(&b, &plain_path, Processor::Cpu, &mut st, &mut mem, &prof, &pool)
            .unwrap();
        let lz = ctl
            .swap_in_file_compressed(&b, &lz_path, Processor::Cpu, &mut st, &mut mem, &prof, &pool)
            .unwrap();
        // The zero-copy invariant holds and the payloads are bitwise equal.
        assert!(lz.data.is_pooled());
        assert_eq!(plain.data.as_slice(), lz.data.as_slice());
        assert_eq!(lz.io_bytes, clen, "only wire bytes crossed the channel");
        assert!(lz.io_bytes < plain.io_bytes / 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn swap_out_frees_everything() {
        let (mut st, mut mem, prof) = setup();
        let ctl = SwapController::new(SwapMode::ZeroCopy, "m");
        let rb = ctl.swap_in_sim(&block(64), 1, Processor::Cpu, &mut st, &mut mem, &prof);
        let before = mem.current();
        let rep = ctl.swap_out(rb, &mut mem, &prof);
        assert_eq!(rep.freed_bytes, 64 * MB);
        assert_eq!(mem.current(), before - 64 * MB);
        assert!(rep.sim_latency_s >= prof.gc_s);
    }

    #[test]
    fn standard_swap_out_leaves_page_cache_resident() {
        // The page-cache copy is NOT owned by the block: freeing the block
        // leaves it cached (the paper's footprint problem).
        let (mut st, mut mem, prof) = setup();
        let ctl = SwapController::new(SwapMode::Standard, "m");
        let rb = ctl.swap_in_sim(&block(64), 1, Processor::Cpu, &mut st, &mut mem, &prof);
        ctl.swap_out(rb, &mut mem, &prof);
        assert!(mem.current_in(Space::PageCache) > 0);
    }

    #[test]
    fn residency_ledger_acquire_release_roundtrip() {
        let (_st, mut mem, _prof) = setup();
        let ctl = SwapController::new(SwapMode::ZeroCopy, "resnet");
        let a = ctl.acquire_residency(&mut mem, 120 * MB);
        let b = ctl.acquire_residency(&mut mem, 40 * MB);
        assert_eq!(mem.current(), 160 * MB);
        assert_eq!(mem.tag_stat("resnet").cur, 160 * MB);
        assert_eq!(ctl.release_residency(&mut mem, a), 120 * MB);
        assert_eq!(ctl.release_residency(&mut mem, b), 40 * MB);
        assert_eq!(mem.current(), 0);
        // Releasing twice is a ledger-discipline violation: the typed
        // error path (not silence) records it.
        assert!(mem.free(a).is_err());
        assert_eq!(mem.ledger_errors, 1);
    }

    #[test]
    fn eviction_drops_the_models_cached_pages_only() {
        // Standard swap-ins of two models leave page-cache residue; the
        // eviction pass must drop exactly the departing model's pages.
        let (mut st, mut mem, prof) = setup();
        let ctl_a = SwapController::new(SwapMode::Standard, "a");
        let ctl_b = SwapController::new(SwapMode::Standard, "b");
        let ra = ctl_a.swap_in_sim(&block(32), 100, Processor::Cpu, &mut st, &mut mem, &prof);
        let rb = ctl_b.swap_in_sim(&block(32), 200, Processor::Cpu, &mut st, &mut mem, &prof);
        ctl_a.swap_out(ra, &mut mem, &prof);
        ctl_b.swap_out(rb, &mut mem, &prof);
        let cached = mem.current_in(Space::PageCache);
        assert!(cached >= 2 * 30 * MB, "both models' pages cached: {cached}");
        ctl_a.evict_files([100u64], &mut st, &mut mem);
        let after = mem.current_in(Space::PageCache);
        assert!(after < cached, "eviction must drop pages");
        assert!(after >= 30 * MB, "the survivor's pages stay cached: {after}");
        // Reacquire is lazy: the next swap-in re-reads (cold misses).
        let again = ctl_a.swap_in_sim(&block(32), 100, Processor::Cpu, &mut st, &mut mem, &prof);
        assert!(again.cache_misses > 0, "evicted file must re-read cold");
    }

    #[test]
    fn real_file_swap_in_carries_bytes() {
        let dir = std::env::temp_dir().join(format!("swapnet-swap-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.bin");
        let bytes: Vec<u8> = (0u8..=255).cycle().take(1 << 20).collect();
        std::fs::write(&path, &bytes).unwrap();
        let (mut st, mut mem, prof) = setup();
        let ctl = SwapController::new(SwapMode::ZeroCopy, "m");
        let mut b = block(1);
        b.size_bytes = bytes.len() as u64;
        let rb = ctl
            .swap_in_file(&b, &path, Processor::Cpu, &mut st, &mut mem, &prof)
            .unwrap();
        assert_eq!(rb.data.as_slice(), &bytes[..]);
        assert!(!rb.data.is_pooled(), "unpooled swap-in carries a detached buffer");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pooled_swap_in_recycles_one_slot() {
        let dir = std::env::temp_dir().join(format!("swapnet-swap-pool-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.bin");
        let bytes: Vec<u8> = (0u8..=255).cycle().take(1 << 18).collect();
        std::fs::write(&path, &bytes).unwrap();
        let (mut st, mut mem, prof) = setup();
        let ctl = SwapController::new(SwapMode::ZeroCopy, "m");
        let mut b = block(1);
        b.size_bytes = bytes.len() as u64;
        let pool = BufferPool::new(bytes.len(), 1);
        for _ in 0..5 {
            let rb = ctl
                .swap_in_file_pooled(&b, &path, Processor::Cpu, &mut st, &mut mem, &prof, &pool)
                .unwrap();
            assert!(rb.data.is_pooled());
            assert_eq!(rb.data.as_slice(), &bytes[..]);
            let out = ctl.swap_out(rb, &mut mem, &prof);
            assert!(out.freed_bytes > 0);
        }
        let s = pool.stats();
        assert_eq!(s.slots, 1, "one slot serves the whole loop");
        assert_eq!(s.alloc_events, 1, "only the warmup allocation");
        assert_eq!(s.reuses, 4);
        assert_eq!(s.checked_out, 0, "swap-out returned the slot");
        std::fs::remove_dir_all(&dir).ok();
    }
}
