//! Content-addressed block store: cross-tenant dedup for swap files and
//! resident block memory (ROADMAP "cross-tenant block dedup + predictive
//! prefetch", after FusedInf's shared-structure loading).
//!
//! Every block file is keyed by the FNV-1a content hash of its layer
//! slice — the same [`crate::util::hash::fnv1a`] the planner's chain
//! fingerprints use — so two tenants cloned from one family resolve to
//! the same key for every block they share. The store then refcounts two
//! independent lifetimes per key:
//!
//!  * `disk_refs` — how many registered tenants reference the block
//!    file. Registration of a second same-family tenant is metadata-only
//!    (no new file bytes); the file is evicted from storage only when the
//!    last referencing tenant is evicted.
//!  * `resident_refs` — how many in-flight batch/prefetch windows hold
//!    the block resident. The `MemSim` ledger is charged exactly once,
//!    when the count goes 0→1, and credited exactly once, when it
//!    returns to 0: shared residency costs one budget slot no matter how
//!    many tenants are executing on it.
//!
//! A [`WindowLease`] snapshots the first `residency_m` blocks of a
//! tenant at acquire time, so re-partitioning (rebudget) between acquire
//! and release can never unbalance the ledger. Leases are what both the
//! demand path (batch start) and the prefetcher hold; a prefetch
//! cancellation is just an early lease release.
//!
//! This module is on the steady-state swap path and inside the
//! virtual-clock domain: `xtask lint` holds it to the no-heap-alloc and
//! no-wall-clock rules.

use std::collections::HashMap;

use crate::memsim::{AllocId, MemSim, Space};
use crate::model::ModelInfo;
use crate::pipeline::SwapVariant;
use crate::util::hash::fnv1a;

/// Ledger tag for shared resident block slots.
pub const RESIDENCY_TAG: &str = "blockstore";

/// Content hash of one block: FNV-1a over the `(size, depth, flops,
/// cut_after)` words of its layer slice — the per-block restriction of
/// the planner's whole-chain `model_fingerprint`, so identical layer
/// runs hash identically across tenants regardless of model name.
pub fn block_hash(model: &ModelInfo, layer_lo: usize, layer_hi: usize) -> u64 {
    fnv1a(model.layers[layer_lo..layer_hi].iter().flat_map(|l| {
        [l.size_bytes, l.depth as u64, l.flops, l.cut_after as u64]
    }))
}

/// Storage file id for a content hash — the canonical mapping lives in
/// [`crate::storage::content_file_id`] (the hash-keyed read path), which
/// keeps the content-addressed id space disjoint from `Storage`'s small
/// incrementing path-registered ids.
pub fn file_id(hash: u64) -> u64 {
    crate::storage::content_file_id(hash)
}

/// Namespace word folded into a block's content hash when the stored
/// file holds its codec-compressed image (DESIGN.md §13): the plain and
/// compressed representations have different bytes on disk, so they must
/// never alias one content-addressed file — while two tenants choosing
/// Compressed for the same slice still dedup to one compressed file.
pub const CODEC_TAG: u64 = 0x434f_4445; // "CODE"

/// Content hash of one block *as stored* under `variant`. Plain and
/// Tiled read the untransformed file (tiling only changes the transfer
/// granularity), so only Compressed leaves the plain namespace.
pub fn codec_hash(hash: u64, variant: SwapVariant) -> u64 {
    match variant {
        SwapVariant::Compressed => fnv1a([hash, CODEC_TAG]),
        SwapVariant::Plain | SwapVariant::Tiled { .. } => hash,
    }
}

/// Storage file id for a block stored under `variant`.
pub fn variant_file_id(hash: u64, variant: SwapVariant) -> u64 {
    crate::storage::content_file_id(codec_hash(hash, variant))
}

/// One block reference: content hash (codec-tagged for compressed
/// storage) plus the bytes its resident copy occupies — the variant's
/// working set, not necessarily the full block.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockRef {
    pub hash: u64,
    pub bytes: u64,
}

/// One content-addressed entry: a block file plus (at most) one resident
/// copy, shared by every tenant whose chain contains this exact slice.
#[derive(Debug)]
struct Entry {
    /// Resident (decompressed working-set) bytes one lease charges.
    bytes: u64,
    /// Bytes the content file occupies on disk (wire bytes for
    /// compressed storage; equal to `bytes` for plain).
    file_bytes: u64,
    file: u64,
    disk_refs: u32,
    resident_refs: u32,
    alloc: Option<AllocId>,
}

/// Per-tenant registration: the block refs in chain order plus the
/// residency window length (first `min(residency_m, n_blocks)` blocks).
#[derive(Debug)]
struct TenantBlocks {
    blocks: Vec<BlockRef>,
    window: usize,
}

/// Result of registering (or re-registering) a tenant's blocks.
#[derive(Debug, Clone, Copy, Default)]
pub struct SyncStats {
    /// Bytes of block files this registration had to materialize.
    pub new_file_bytes: u64,
    /// Bytes satisfied by files other tenants already own — the
    /// metadata-only portion of the registration.
    pub dedup_bytes: u64,
}

/// A held residency window: proof that the ledger was charged for the
/// snapshot's blocks. Must be returned to [`BlockStore::release_window`]
/// (batch completion or prefetch cancellation) to credit the ledger.
#[derive(Debug)]
pub struct WindowLease {
    tenant: usize,
    blocks: Vec<BlockRef>,
}

impl WindowLease {
    pub fn tenant(&self) -> usize {
        self.tenant
    }

    /// Total bytes the window spans (charged + shared).
    pub fn window_bytes(&self) -> u64 {
        self.blocks.iter().map(|b| b.bytes).sum()
    }
}

/// Result of acquiring a residency window.
#[derive(Debug)]
pub struct WindowAcquire {
    pub lease: WindowLease,
    /// Bytes newly charged to the ledger (blocks that were not resident).
    pub charged_bytes: u64,
    /// Bytes already resident under another lease — the shared-hit bytes
    /// this acquire got for free.
    pub shared_bytes: u64,
}

/// The content-addressed block store (see module docs).
#[derive(Debug, Default)]
pub struct BlockStore {
    entries: HashMap<u64, Entry>,
    tenants: Vec<Option<TenantBlocks>>,
    logical_bytes: u64,
    unique_bytes: u64,
    /// Files whose last disk ref left while a lease still held them
    /// resident; drained by the caller once the lease returns.
    stale_files: Vec<u64>,
}

impl BlockStore {
    pub fn new() -> BlockStore {
        BlockStore::default()
    }

    /// Register (or re-register after a rebudget) tenant `tenant`'s
    /// blocks: the partition `points` of `model`, windowed to the first
    /// `residency_m` blocks, with every block stored and charged Plain.
    /// Existing refs for the tenant are released first, so calling this
    /// after every re-plan is idempotent for an unchanged partition.
    pub fn sync_tenant(
        &mut self,
        tenant: usize,
        model: &ModelInfo,
        points: &[usize],
        residency_m: usize,
    ) -> Result<SyncStats, String> {
        self.sync_tenant_variants(tenant, model, points, residency_m, &[])
    }

    /// [`sync_tenant`](Self::sync_tenant) with the planner's per-block
    /// swap variants: compressed blocks register their codec-tagged
    /// content file at wire bytes (dedup still applies across clones
    /// that chose the same variant), tiled blocks charge their tile
    /// working set at residency instead of the full block. `variants`
    /// must be empty (all-Plain) or one per block.
    pub fn sync_tenant_variants(
        &mut self,
        tenant: usize,
        model: &ModelInfo,
        points: &[usize],
        residency_m: usize,
        variants: &[SwapVariant],
    ) -> Result<SyncStats, String> {
        let blocks = model.create_blocks(points)?;
        if !variants.is_empty() && variants.len() != blocks.len() {
            return Err(format!(
                "{}: {} variants for {} blocks",
                model.name,
                variants.len(),
                blocks.len()
            ));
        }
        if self.tenants.len() <= tenant {
            self.tenants.resize_with(tenant + 1, || None);
        }
        // Release the previous registration before inserting the new one
        // so an unchanged partition nets out to a no-op.
        for f in self.drop_tenant_refs(tenant) {
            self.stale_files.push(f);
        }

        let mut refs = Vec::new();
        let mut stats = SyncStats::default();
        for (i, b) in blocks.iter().enumerate() {
            let v = variants.get(i).copied().unwrap_or(SwapVariant::Plain);
            let hash = codec_hash(block_hash(model, b.layer_lo, b.layer_hi), v);
            let resident = v.working_set(b.size_bytes);
            let file_bytes = match v {
                SwapVariant::Compressed => {
                    (b.size_bytes as f64 * crate::codec::PLANNED_RATIO).ceil() as u64
                }
                SwapVariant::Plain | SwapVariant::Tiled { .. } => b.size_bytes,
            };
            let r = BlockRef { hash, bytes: resident };
            let e = self.entries.entry(hash).or_insert(Entry {
                bytes: resident,
                file_bytes,
                file: file_id(hash),
                disk_refs: 0,
                resident_refs: 0,
                alloc: None,
            });
            debug_assert_eq!(e.file_bytes, file_bytes, "content hash collision");
            // Tenants may window the same content at different tile
            // working sets; the entry charges the largest so the shared
            // resident copy covers every reader.
            e.bytes = e.bytes.max(resident);
            if e.disk_refs == 0 {
                stats.new_file_bytes += file_bytes;
                self.unique_bytes += file_bytes;
            } else {
                stats.dedup_bytes += file_bytes;
            }
            e.disk_refs += 1;
            self.logical_bytes += file_bytes;
            refs.push(r);
        }
        let window = residency_m.max(1).min(refs.len());
        self.tenants[tenant] = Some(TenantBlocks { blocks: refs, window });
        Ok(stats)
    }

    /// Evict tenant `tenant`: drop its disk refs and return the file ids
    /// whose last reference just left (the caller evicts those from
    /// `Storage`). Files still pinned resident by an outstanding lease
    /// are deferred to [`take_stale_files`](Self::take_stale_files).
    pub fn release_tenant(&mut self, tenant: usize) -> Vec<u64> {
        let freed = self.drop_tenant_refs(tenant);
        if let Some(slot) = self.tenants.get_mut(tenant) {
            *slot = None;
        }
        freed
    }

    fn drop_tenant_refs(&mut self, tenant: usize) -> Vec<u64> {
        let mut freed = Vec::new();
        let Some(Some(tb)) = self.tenants.get_mut(tenant).map(Option::take) else {
            return freed;
        };
        for r in &tb.blocks {
            let Some(e) = self.entries.get_mut(&r.hash) else {
                debug_assert!(false, "disk ref without entry");
                continue;
            };
            e.disk_refs -= 1;
            self.logical_bytes -= e.file_bytes;
            if e.disk_refs == 0 {
                self.unique_bytes -= e.file_bytes;
                if e.resident_refs == 0 {
                    freed.push(e.file);
                    self.entries.remove(&r.hash);
                }
                // else: a lease still holds it; release_window will move
                // the file id into stale_files when the lease returns.
            }
        }
        freed
    }

    /// Charge the ledger for tenant `tenant`'s residency window and hand
    /// back the lease. Blocks already resident under another lease are
    /// shared for free; only 0→1 transitions allocate. Returns `None`
    /// for an unregistered tenant.
    pub fn acquire_window(&mut self, tenant: usize, mem: &mut MemSim) -> Option<WindowAcquire> {
        // lint: allow(alloc-pairing): the charge travels inside the
        // WindowLease and is credited by release_window when the batch
        // retires or the prefetch cancels.
        let tb = self.tenants.get(tenant)?.as_ref()?;
        let mut snapshot = Vec::new();
        for r in &tb.blocks[..tb.window] {
            snapshot.push(*r);
        }
        let mut charged = 0u64;
        let mut shared = 0u64;
        for r in &snapshot {
            let e = self.entries.get_mut(&r.hash).expect("windowed block has an entry");
            if e.resident_refs == 0 {
                // Charge the entry's resident bytes (the max working set
                // over referencing tenants), not this lease's view, so
                // the shared copy covers every reader.
                e.alloc = Some(mem.alloc(RESIDENCY_TAG, Space::Unified, e.bytes));
                charged += e.bytes;
            } else {
                shared += r.bytes;
            }
            e.resident_refs += 1;
        }
        Some(WindowAcquire {
            lease: WindowLease { tenant, blocks: snapshot },
            charged_bytes: charged,
            shared_bytes: shared,
        })
    }

    /// Credit the ledger for a lease: each block's 1→0 transition frees
    /// its slot. Returns the bytes credited back.
    pub fn release_window(&mut self, lease: WindowLease, mem: &mut MemSim) -> u64 {
        let mut freed = 0u64;
        for r in &lease.blocks {
            let Some(e) = self.entries.get_mut(&r.hash) else {
                debug_assert!(false, "lease over a vanished entry");
                continue;
            };
            e.resident_refs -= 1;
            if e.resident_refs == 0 {
                if let Some(id) = e.alloc.take() {
                    freed += mem.must_free(id);
                }
                if e.disk_refs == 0 {
                    // Last disk ref left while we were resident: the file
                    // eviction was deferred to us.
                    self.stale_files.push(e.file);
                    self.entries.remove(&r.hash);
                }
            }
        }
        freed
    }

    /// Drain file ids whose eviction was deferred past a lease release.
    pub fn take_stale_files(&mut self) -> Vec<u64> {
        std::mem::take(&mut self.stale_files)
    }

    /// Bytes of tenant `tenant`'s residency window already resident under
    /// some lease — the warm bytes a demand swap-in would get for free
    /// right now (from a prefetch or a concurrent same-family tenant).
    pub fn resident_overlap_bytes(&self, tenant: usize) -> u64 {
        let Some(Some(tb)) = self.tenants.get(tenant) else {
            return 0;
        };
        tb.blocks[..tb.window]
            .iter()
            .filter(|r| {
                self.entries
                    .get(&r.hash)
                    .is_some_and(|e| e.resident_refs > 0)
            })
            .map(|r| r.bytes)
            .sum()
    }

    /// Total bytes of tenant `tenant`'s residency window.
    pub fn window_bytes(&self, tenant: usize) -> u64 {
        let Some(Some(tb)) = self.tenants.get(tenant) else {
            return 0;
        };
        tb.blocks[..tb.window].iter().map(|r| r.bytes).sum()
    }

    /// Registered bytes as tenants see them (every tenant counts its own
    /// full chain).
    pub fn logical_bytes(&self) -> u64 {
        self.logical_bytes
    }

    /// Bytes actually on disk: each content-addressed file once.
    pub fn unique_bytes(&self) -> u64 {
        self.unique_bytes
    }

    /// Registered-but-deduplicated bytes (`logical - unique`).
    pub fn dedup_bytes(&self) -> u64 {
        self.logical_bytes - self.unique_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::families;

    fn store_with_clones(n: usize) -> (BlockStore, Vec<ModelInfo>, Vec<usize>) {
        let base = families::resnet101();
        let points: Vec<usize> = base.legal_cut_points().into_iter().take(3).collect();
        let mut models = Vec::new();
        for i in 0..n {
            let mut m = base.clone();
            m.name = format!("resnet101-{i}");
            models.push(m);
        }
        let mut bs = BlockStore::new();
        for (i, m) in models.iter().enumerate() {
            bs.sync_tenant(i, m, &points, 2).expect("legal points");
        }
        (bs, models, points)
    }

    #[test]
    fn clones_dedup_to_one_file_set() {
        let (bs, models, _) = store_with_clones(4);
        let one = models[0].size_bytes();
        assert_eq!(bs.logical_bytes(), 4 * one);
        assert_eq!(bs.unique_bytes(), one, "clones share every block file");
        assert_eq!(bs.dedup_bytes(), 3 * one);
    }

    #[test]
    fn sync_stats_report_metadata_only_registration() {
        let base = families::resnet101();
        let points: Vec<usize> = base.legal_cut_points().into_iter().take(2).collect();
        let mut bs = BlockStore::new();
        let first = bs.sync_tenant(0, &base, &points, 2).expect("plan");
        assert_eq!(first.new_file_bytes, base.size_bytes());
        assert_eq!(first.dedup_bytes, 0);
        let mut clone = base.clone();
        clone.name = "resnet101-b".into();
        let second = bs.sync_tenant(1, &clone, &points, 2).expect("plan");
        assert_eq!(second.new_file_bytes, 0, "second registration is metadata-only");
        assert_eq!(second.dedup_bytes, base.size_bytes());
    }

    #[test]
    fn resync_same_partition_is_a_net_noop() {
        let (mut bs, models, points) = store_with_clones(2);
        let before = (bs.logical_bytes(), bs.unique_bytes());
        let s = bs.sync_tenant(0, &models[0], &points, 2).expect("plan");
        assert_eq!((bs.logical_bytes(), bs.unique_bytes()), before);
        assert_eq!(s.new_file_bytes, 0, "all blocks still referenced by tenant 1");
    }

    #[test]
    fn shared_window_charges_the_ledger_once() {
        let (mut bs, _, _) = store_with_clones(2);
        let mut mem = MemSim::new(u64::MAX);
        let w0 = bs.window_bytes(0);
        assert!(w0 > 0);
        let a = bs.acquire_window(0, &mut mem).expect("registered");
        assert_eq!(a.charged_bytes, w0);
        assert_eq!(a.shared_bytes, 0);
        assert_eq!(mem.current(), w0);
        // Same-family tenant 1's window is fully shared: zero new charge.
        let b = bs.acquire_window(1, &mut mem).expect("registered");
        assert_eq!(b.charged_bytes, 0);
        assert_eq!(b.shared_bytes, w0);
        assert_eq!(mem.current(), w0, "shared residency is charged once");
        // First release keeps the blocks resident (tenant 1 still holds
        // them); the last release credits everything back.
        assert_eq!(bs.release_window(a.lease, &mut mem), 0);
        assert_eq!(mem.current(), w0);
        assert_eq!(bs.release_window(b.lease, &mut mem), w0);
        assert_eq!(mem.current(), 0);
        assert_eq!(mem.ledger_errors, 0);
    }

    #[test]
    fn overlap_reports_warm_bytes() {
        let (mut bs, _, _) = store_with_clones(2);
        let mut mem = MemSim::new(u64::MAX);
        assert_eq!(bs.resident_overlap_bytes(1), 0);
        let a = bs.acquire_window(0, &mut mem).expect("registered");
        assert_eq!(bs.resident_overlap_bytes(1), bs.window_bytes(1));
        bs.release_window(a.lease, &mut mem);
        assert_eq!(bs.resident_overlap_bytes(1), 0);
    }

    #[test]
    fn eviction_keeps_shared_files_until_last_ref() {
        let (mut bs, models, _) = store_with_clones(2);
        let freed = bs.release_tenant(0);
        assert!(freed.is_empty(), "tenant 1 still references every file");
        assert_eq!(bs.unique_bytes(), models[0].size_bytes());
        let freed = bs.release_tenant(1);
        assert_eq!(freed.len(), 4, "last ref frees all 4 block files");
        assert_eq!(bs.unique_bytes(), 0);
        assert_eq!(bs.logical_bytes(), 0);
    }

    #[test]
    fn eviction_under_a_live_lease_defers_file_removal() {
        let (mut bs, _, _) = store_with_clones(1);
        let mut mem = MemSim::new(u64::MAX);
        let a = bs.acquire_window(0, &mut mem).expect("registered");
        let freed = bs.release_tenant(0);
        // Window files (2 of 4 blocks) stay pinned by the lease; the
        // other block files free immediately.
        assert_eq!(freed.len(), 2);
        assert!(bs.take_stale_files().is_empty());
        bs.release_window(a.lease, &mut mem);
        assert_eq!(bs.take_stale_files().len(), 2, "deferred evictions surface");
        assert_eq!(mem.current(), 0);
    }

    #[test]
    fn distinct_families_share_nothing() {
        let points_a: Vec<usize> =
            families::resnet101().legal_cut_points().into_iter().take(3).collect();
        let points_b: Vec<usize> =
            families::vgg19().legal_cut_points().into_iter().take(3).collect();
        let mut bs = BlockStore::new();
        bs.sync_tenant(0, &families::resnet101(), &points_a, 2).expect("plan");
        bs.sync_tenant(1, &families::vgg19(), &points_b, 2).expect("plan");
        assert_eq!(bs.dedup_bytes(), 0);
        assert_eq!(
            bs.unique_bytes(),
            families::resnet101().size_bytes() + families::vgg19().size_bytes()
        );
    }

    #[test]
    fn compressed_variant_registers_codec_tagged_wire_bytes() {
        let base = families::resnet101();
        let points: Vec<usize> = base.legal_cut_points().into_iter().take(2).collect();
        let n = points.len() + 1;
        let mut bs = BlockStore::new();
        let plain = bs.sync_tenant(0, &base, &points, 2).unwrap();
        let mut clone = base.clone();
        clone.name = "resnet101-lz".into();
        let comp = bs
            .sync_tenant_variants(1, &clone, &points, 2, &vec![SwapVariant::Compressed; n])
            .unwrap();
        // Different namespace: nothing dedups against the plain files,
        // and the compressed registration costs wire bytes on disk.
        assert_eq!(comp.dedup_bytes, 0);
        assert!(comp.new_file_bytes < plain.new_file_bytes, "{comp:?} vs {plain:?}");
        // A second compressed clone dedups fully inside the codec
        // namespace.
        let mut c2 = base.clone();
        c2.name = "resnet101-lz2".into();
        let again = bs
            .sync_tenant_variants(2, &c2, &points, 2, &vec![SwapVariant::Compressed; n])
            .unwrap();
        assert_eq!(again.new_file_bytes, 0);
        assert_eq!(again.dedup_bytes, comp.new_file_bytes);
        // Residency still charges the decompressed block, not wire bytes.
        let mut mem = MemSim::new(u64::MAX);
        let a = bs.acquire_window(1, &mut mem).unwrap();
        assert_eq!(a.charged_bytes, bs.window_bytes(1));
        bs.release_window(a.lease, &mut mem);
        assert_eq!(mem.current(), 0);
    }

    #[test]
    fn tiled_variant_shares_plain_files_but_windows_its_working_set() {
        let base = families::resnet101();
        let points: Vec<usize> = base.legal_cut_points().into_iter().take(2).collect();
        let n = points.len() + 1;
        let mut bs = BlockStore::new();
        let t = bs
            .sync_tenant_variants(0, &base, &points, 2, &vec![SwapVariant::Tiled { t: 4 }; n])
            .unwrap();
        assert_eq!(t.new_file_bytes, base.size_bytes(), "tiling reads the plain files");
        let mut plain_clone = base.clone();
        plain_clone.name = "resnet101-p".into();
        bs.sync_tenant(1, &plain_clone, &points, 2).unwrap();
        // The tile working set bounds the resident window below plain.
        assert!(bs.window_bytes(0) < bs.window_bytes(1));
        // Same namespace: the plain clone dedups against the tiled files.
        assert_eq!(bs.dedup_bytes(), base.size_bytes());
        let mut mem = MemSim::new(u64::MAX);
        let a = bs.acquire_window(0, &mut mem).unwrap();
        // Shared entries charge the max working set over their tenants
        // (here the plain clone's full blocks cover the tiled reader).
        assert_eq!(a.charged_bytes, bs.window_bytes(1));
        bs.release_window(a.lease, &mut mem);
        assert_eq!(mem.current(), 0);
        assert_eq!(mem.ledger_errors, 0);
    }

    #[test]
    fn block_hash_matches_planner_fingerprint_domain() {
        // Whole-chain block hash == the planner's model_fingerprint: both
        // are fnv1a over the same per-layer words, so a one-block
        // partition and the plan-cache key agree exactly.
        let m = families::resnet101();
        assert_eq!(
            block_hash(&m, 0, m.layers.len()),
            crate::planner::cost::model_fingerprint(&m)
        );
    }
}
