//! Power model (paper Fig 19b, INA3221 substitute).
//!
//! Integrates the device profile's component draws over an execution
//! timeline: idle + CPU/GPU-active during block execution + I/O-active
//! during swap transfers. Reproduces the Fig 19b shape: SwapNet draws
//! ~0.3 W more than DInf while running (swap I/O active) but its curve
//! leads DInf's because assembly is faster.

use crate::config::{DeviceProfile, Processor};
use crate::pipeline::Timeline;

/// A sampled power trace.
#[derive(Debug, Clone)]
pub struct PowerTrace {
    pub dt_s: f64,
    pub watts: Vec<f64>,
}

impl PowerTrace {
    pub fn duration_s(&self) -> f64 {
        self.dt_s * self.watts.len() as f64
    }

    pub fn avg_w(&self) -> f64 {
        crate::util::stats::mean(&self.watts)
    }

    /// Average draw over the busy (non-idle-tail) part only.
    pub fn avg_active_w(&self, prof: &DeviceProfile) -> f64 {
        let active: Vec<f64> = self
            .watts
            .iter()
            .copied()
            .filter(|w| *w > prof.power.idle_w + 1e-9)
            .collect();
        crate::util::stats::mean(&active)
    }

    /// Mean draw while the processor is executing (what the INA3221
    /// shows during "a model is running" in Fig 19b — the swap channel's
    /// draw appears only where it overlaps execution).
    pub fn avg_exec_busy_w(&self, prof: &DeviceProfile, proc: Processor) -> f64 {
        let floor = prof.power.idle_w
            + match proc {
                Processor::Cpu => prof.power.cpu_active_w,
                Processor::Gpu => prof.power.gpu_active_w,
            };
        let busy: Vec<f64> = self
            .watts
            .iter()
            .copied()
            .filter(|w| *w >= floor - 1e-9)
            .collect();
        crate::util::stats::mean(&busy)
    }

    pub fn peak_w(&self) -> f64 {
        self.watts.iter().copied().fold(0.0, f64::max)
    }

    pub fn energy_j(&self) -> f64 {
        self.watts.iter().sum::<f64>() * self.dt_s
    }
}

fn busy(intervals: &[(f64, f64)], t: f64) -> bool {
    intervals.iter().any(|&(a, b)| t >= a && t < b)
}

/// Sample the power draw of one model execution timeline.
pub fn trace_for_timeline(
    tl: &Timeline,
    proc: Processor,
    prof: &DeviceProfile,
    dt_s: f64,
    tail_s: f64,
) -> PowerTrace {
    let end = tl.latency() + tail_s;
    let io = tl.io_busy();
    let ex = tl.exec_busy();
    let n = (end / dt_s).ceil() as usize;
    let mut watts = Vec::with_capacity(n);
    for k in 0..n {
        let t = k as f64 * dt_s;
        let mut w = prof.power.idle_w;
        if busy(&ex, t) {
            w += match proc {
                Processor::Cpu => prof.power.cpu_active_w,
                Processor::Gpu => prof.power.gpu_active_w,
            };
        }
        if busy(&io, t) {
            w += prof.power.io_active_w;
        }
        watts.push(w);
    }
    PowerTrace { dt_s, watts }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::{timeline, BlockTimes};

    fn tl(n: usize) -> Timeline {
        timeline(&vec![BlockTimes { t_in: 0.05, t_ex: 0.2, t_out: 0.03 }; n])
    }

    #[test]
    fn idle_tail_draws_idle_power() {
        let prof = DeviceProfile::jetson_nx();
        let tr = trace_for_timeline(&tl(2), Processor::Cpu, &prof, 0.01, 0.5);
        let last = *tr.watts.last().unwrap();
        assert!((last - prof.power.idle_w).abs() < 1e-9);
    }

    #[test]
    fn active_power_above_idle_below_budget() {
        let prof = DeviceProfile::jetson_nx();
        let tr = trace_for_timeline(&tl(3), Processor::Cpu, &prof, 0.005, 0.0);
        assert!(tr.peak_w() >= prof.power.idle_w + prof.power.cpu_active_w - 1e-9);
        assert!(tr.avg_w() > prof.power.idle_w);
        // Paper: running draw ~6 W on NX, idle ~3 W.
        assert!(tr.peak_w() < 8.0, "{}", tr.peak_w());
    }

    #[test]
    fn energy_scales_with_work() {
        let prof = DeviceProfile::jetson_nx();
        let a = trace_for_timeline(&tl(2), Processor::Gpu, &prof, 0.01, 0.0);
        let b = trace_for_timeline(&tl(4), Processor::Gpu, &prof, 0.01, 0.0);
        assert!(b.energy_j() > a.energy_j());
    }
}
