//! Device profiles and experiment configuration.
//!
//! A [`DeviceProfile`] carries everything the simulators need to model one
//! edge AI device: the paper's four delay coefficients (alpha, beta, gamma,
//! eta — §6.1), the standard-path costs that SwapNet eliminates (page-cache
//! reads, CPU->GPU format conversion, dummy-model assembly), the memory
//! architecture, and the power model. Two calibrated profiles ship:
//! Jetson Xavier NX and Jetson Nano (§8.1.3), with coefficients derived
//! from the paper's reported numbers (ResNet-101 ~466 ms in 3 blocks on
//! NX, 52 us per address reference, ~30 ms GC, NVMe ~3.5 GB/s) — the
//! calibration is documented in DESIGN.md §1.

pub const KB: u64 = 1_000;
pub const MB: u64 = 1_000_000;
pub const GB: u64 = 1_000_000_000;

/// Which processor executes a model (paper §8.1.2 assigns VGG/ResNet to
/// CPU and YOLO/FCN to GPU).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Processor {
    Cpu,
    Gpu,
}

impl std::fmt::Display for Processor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Processor::Cpu => write!(f, "CPU"),
            Processor::Gpu => write!(f, "GPU"),
        }
    }
}

/// Power model components (Fig 19b).
#[derive(Debug, Clone)]
pub struct PowerProfile {
    /// Device idle draw (paper: ~3 W).
    pub idle_w: f64,
    /// Added draw while a model executes on CPU.
    pub cpu_active_w: f64,
    /// Added draw while a model executes on GPU.
    pub gpu_active_w: f64,
    /// Added draw during swap I/O (DMA + SSD).
    pub io_active_w: f64,
}

/// Everything the simulators need to know about one device.
#[derive(Debug, Clone)]
pub struct DeviceProfile {
    pub name: String,
    pub mem_total: u64,

    // ---- paper §6.1 delay-model coefficients -------------------------
    /// alpha: swap-in seconds per byte over the direct-I/O DMA channel
    /// (t_in/sw = alpha * s_i).
    pub alpha_s_per_byte: f64,
    /// beta: seconds per parameter-depth unit for assembly by reference
    /// (t_in/as = beta * d_i; paper measures 50-55 us per reference).
    pub beta_s_per_depth: f64,
    /// gamma: execution seconds per FLOP on each processor
    /// (t_ex = gamma * f_i).
    pub gamma_cpu_s_per_flop: f64,
    pub gamma_gpu_s_per_flop: f64,
    /// eta: seconds per depth unit to reset skeleton pointers at swap-out
    /// (t_out = eta * d_i + gc).
    pub eta_s_per_depth: f64,
    /// Garbage-collection latency per swap-out (paper: ~30 ms).
    pub gc_s: f64,
    /// Fixed DMA transfer setup per swap-in, folded into t_in. Owned by
    /// the profile (it is a device property, not a scheduler constant).
    pub dma_setup_s: f64,
    /// Per-block serial dispatch cost on the execution critical path:
    /// thread wake-up/switch + kernel dispatch between blocks — the
    /// overhead behind the paper's m = 2 cap and Fig 16's latency growth
    /// with block count.
    pub dispatch_s_per_block: f64,
    /// CPU seconds per *uncompressed* byte to decompress a block read
    /// through the swap codec (the compressed-variant trade: fewer IO
    /// bytes for this CPU cost). LZ-style decompression streams near
    /// memcpy speed on the NX Carmel cores and proportionally slower on
    /// the Nano's A57s — the ratio is what makes the planner's variant
    /// choice device-dependent.
    pub decompress_s_per_byte: f64,
    /// Extra serial dispatch cost per additional sub-block tile when a
    /// block's swap+exec is split into `t` tiles (the tiled variant's
    /// latency price for its smaller working set).
    pub tile_dispatch_s: f64,

    // ---- standard-path costs SwapNet bypasses ------------------------
    /// Buffered (page-cache) read bandwidth on a cache miss.
    pub cached_read_s_per_byte: f64,
    /// Page-cache hit copy bandwidth.
    pub cache_hit_s_per_byte: f64,
    /// Extra per-read page-cache management overhead (variable latency —
    /// scaled up under memory pressure).
    pub cache_mgmt_s: f64,
    /// Plain memcpy bandwidth (dummy-model parameter copies).
    pub memcpy_s_per_byte: f64,
    /// CPU->GPU dispatch: format conversion + copy into the "fake" GPU
    /// region of the shared SoC memory (the .to('cuda') path).
    pub gpu_convert_s_per_byte: f64,
    /// Fixed CUDA-dispatch overhead per .to('cuda') call.
    pub gpu_dispatch_s: f64,
    /// Model-object instantiation cost per parameter tensor when a dummy
    /// model is built (naive assembly, §5.1).
    pub dummy_instantiate_s_per_depth: f64,

    pub power: PowerProfile,
}

impl DeviceProfile {
    /// Jetson Xavier NX (8 GB, 1.9 GHz Carmel CPU, 1.1 GHz Volta GPU).
    pub fn jetson_nx() -> Self {
        DeviceProfile {
            name: "jetson-nx".into(),
            mem_total: 8 * GB,
            // 970 EVO Plus over DMA: ~3.5 GB/s, stable.
            alpha_s_per_byte: 1.0 / (3.5e9),
            // paper: 50-55 us per address reference.
            beta_s_per_depth: 52e-6,
            // ResNet-101 (~15.6 GFLOP @224) in ~451 ms on the Carmel CPU.
            gamma_cpu_s_per_flop: 2.89e-11,
            // Volta iGPU roughly 10x the CPU on conv workloads.
            gamma_gpu_s_per_flop: 2.9e-12,
            eta_s_per_depth: 20e-6,
            gc_s: 30e-3,
            // NVMe DMA engine setup per transfer.
            dma_setup_s: 150e-6,
            // Carmel thread wake-up + dispatch between blocks.
            dispatch_s_per_block: 3.5e-3,
            // LZ-style decompress streams ~9 GB/s on the Carmel cores —
            // cheaper than the DMA bytes it saves, so compression wins
            // here when IO binds.
            decompress_s_per_byte: 1.0 / 9.0e9,
            // Sub-block tile dispatch: a fraction of the full block
            // dispatch (no thread wake-up, just another kernel launch).
            tile_dispatch_s: 1.0e-3,
            // Buffered reads land around 2.2 GB/s and leave a cache copy.
            cached_read_s_per_byte: 1.0 / 2.2e9,
            cache_hit_s_per_byte: 1.0 / 10e9,
            cache_mgmt_s: 1.2e-3,
            memcpy_s_per_byte: 1.0 / 8e9,
            // .to('cuda'): format conversion + copy, ~1.6 GB/s effective.
            gpu_convert_s_per_byte: 1.0 / 1.6e9,
            gpu_dispatch_s: 4e-3,
            dummy_instantiate_s_per_depth: 320e-6,
            power: PowerProfile {
                idle_w: 3.0,
                cpu_active_w: 2.6,
                gpu_active_w: 3.1,
                // NVMe + DMA engine draw during active transfers (the 970
                // EVO Plus peaks well above this).
                io_active_w: 2.0,
            },
        }
    }

    /// Jetson Nano (4 GB, 1.4 GHz CPU, 0.6 GHz Maxwell GPU).
    pub fn jetson_nano() -> Self {
        let nx = Self::jetson_nx();
        DeviceProfile {
            name: "jetson-nano".into(),
            mem_total: 4 * GB,
            gamma_cpu_s_per_flop: nx.gamma_cpu_s_per_flop * 1.36,
            gamma_gpu_s_per_flop: nx.gamma_gpu_s_per_flop * 1.9,
            beta_s_per_depth: 62e-6,
            eta_s_per_depth: 25e-6,
            gc_s: 34e-3,
            // Slower DMA setup and thread dispatch on the Nano's A57s
            // (scaled like the other coefficients, ~1.2x the NX).
            dma_setup_s: 180e-6,
            dispatch_s_per_block: 4.2e-3,
            // The A57s decompress ~1.4x slower than the Carmel cores —
            // slow enough that the bytes saved no longer pay for the CPU
            // time, so the Nano's planner keeps Plain where the NX
            // chooses Compressed.
            decompress_s_per_byte: 1.36 / 9.0e9,
            tile_dispatch_s: 1.2e-3,
            cache_mgmt_s: 1.6e-3,
            dummy_instantiate_s_per_depth: 410e-6,
            power: PowerProfile {
                idle_w: 2.2,
                cpu_active_w: 2.0,
                gpu_active_w: 2.3,
                io_active_w: 1.6,
            },
            ..nx
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "jetson-nx" | "nx" => Some(Self::jetson_nx()),
            "jetson-nano" | "nano" => Some(Self::jetson_nano()),
            _ => None,
        }
    }

    pub fn gamma(&self, proc: Processor) -> f64 {
        match proc {
            Processor::Cpu => self.gamma_cpu_s_per_flop,
            Processor::Gpu => self.gamma_gpu_s_per_flop,
        }
    }
}

/// Fraction of a model's budget reserved for skeleton + activations +
/// lookup tables (the paper's delta in Eq. 3; §8.5 measures ~3.6%).
pub const DELTA: f64 = 0.036;

/// Parallel block residency (paper fixes m = 2: one block executing while
/// the next swaps in).
pub const PARALLELISM_M: usize = 2;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nx_profile_sane() {
        let p = DeviceProfile::jetson_nx();
        assert_eq!(p.mem_total, 8 * GB);
        // alpha: 100 MB block should swap in around 29 ms.
        let t = p.alpha_s_per_byte * 100.0e6;
        assert!((0.02..0.04).contains(&t), "swap-in {t}");
        // beta in the paper's measured 50-55us band.
        assert!((50e-6..=55e-6).contains(&p.beta_s_per_depth));
        // ResNet-101-scale model ~15.6 GFLOP near 451 ms on CPU.
        let ex = p.gamma_cpu_s_per_flop * 15.6e9;
        assert!((0.40..0.50).contains(&ex), "exec {ex}");
    }

    #[test]
    fn nano_slower_than_nx() {
        let nx = DeviceProfile::jetson_nx();
        let nano = DeviceProfile::jetson_nano();
        assert!(nano.gamma_cpu_s_per_flop > nx.gamma_cpu_s_per_flop);
        assert!(nano.mem_total < nx.mem_total);
        assert_eq!(nano.alpha_s_per_byte, nx.alpha_s_per_byte);
    }

    #[test]
    fn by_name_lookup() {
        assert!(DeviceProfile::by_name("nx").is_some());
        assert!(DeviceProfile::by_name("jetson-nano").is_some());
        assert!(DeviceProfile::by_name("h100").is_none());
    }

    #[test]
    fn gpu_faster_than_cpu() {
        let p = DeviceProfile::jetson_nx();
        assert!(p.gamma(Processor::Gpu) < p.gamma(Processor::Cpu));
    }
}
