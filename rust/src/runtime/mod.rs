//! PJRT runtime: load AOT-lowered HLO text artifacts and execute model
//! units from the Rust request path (Python is never involved here).
//!
//! Pattern (see /opt/xla-example/load_hlo): HLO *text* -> HloModuleProto
//! -> XlaComputation -> PjRtClient::compile -> execute. Executables are
//! cached per (unit, batch) — compilation happens once at model-register
//! time, mirroring SwapNet keeping skeletons resident while parameters
//! swap.
//!
//! NOTE: the xla crate's handles wrap raw pointers (!Send), so the
//! runtime is thread-confined; the real pipeline overlaps *file I/O* on a
//! second thread and keeps all PJRT calls on the executor thread — which
//! is exactly SwapNet's swap-in/execute overlap boundary.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::assembly::{param_slice, ParamRef};
use crate::model::artifacts::{ArtifactModel, UnitMeta};

/// Thread-confined PJRT runtime with an executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// Cumulative compile time (s) — reported by the perf pass.
    pub compile_s: RefCell<f64>,
}

impl Runtime {
    /// CPU PJRT client (the only backend in this environment; real
    /// devices would select cuda/tpu plugins here).
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e:?}"))?;
        Ok(Runtime {
            client,
            cache: RefCell::new(HashMap::new()),
            compile_s: RefCell::new(0.0),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file (cached by canonical path).
    pub fn load_hlo(&self, path: &Path) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        let key = path.to_string_lossy().to_string();
        if let Some(e) = self.cache.borrow().get(&key) {
            return Ok(e.clone());
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
        *self.compile_s.borrow_mut() += t0.elapsed().as_secs_f64();
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }

    pub fn cached_executables(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Execute one unit: `fwd(act, *params) -> (act_out,)`.
    pub fn execute_unit(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        act: &xla::Literal,
        params: &[xla::Literal],
    ) -> Result<xla::Literal> {
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(1 + params.len());
        args.push(act);
        args.extend(params.iter());
        let out = exe
            .execute::<&xla::Literal>(&args)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        // Pallas artifacts are lowered with return_tuple=True (1-tuple);
        // ref artifacts return a bare array. Handle both.
        let fallback = lit.clone();
        Ok(lit.to_tuple1().unwrap_or(fallback))
    }

    /// Upload host f32 data as a device buffer (resident parameters).
    ///
    /// Uses `buffer_from_host_buffer` (kImmutableOnlyDuringCall: the copy
    /// completes before returning). `BufferFromHostLiteral` on the TFRT
    /// CPU client is ASYNC — it can read the literal after this function
    /// returns, a use-after-free with temporaries.
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload: {e:?}"))
    }

    /// Upload raw little-endian f32 bytes as a device buffer in a
    /// single pass. XLA literals are little-endian on every target, so
    /// on LE hosts with a 4-byte-aligned source the bytes already ARE
    /// the device layout and go straight to the backend (one copy, no
    /// element-wise conversion — the seed implementation converted
    /// bytes -> `Vec<f32>` -> device, two full passes with an extra
    /// allocation per upload). Misaligned or big-endian sources take
    /// the one-pass conversion route.
    pub fn upload_f32_bytes(&self, bytes: &[u8], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        let expected: usize = dims.iter().product::<usize>() * 4;
        if bytes.len() != expected {
            return Err(anyhow!(
                "upload bytes {} != shape {:?} ({} bytes)",
                bytes.len(),
                dims,
                expected
            ));
        }
        let aligned = bytes.as_ptr().align_offset(std::mem::align_of::<f32>()) == 0;
        if cfg!(target_endian = "little") && aligned {
            return self
                .client
                .buffer_from_host_f32_bytes(bytes, dims)
                .map_err(|e| anyhow!("upload bytes: {e:?}"));
        }
        let vals: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        self.upload_f32(&vals, dims)
    }

    /// Execute a (non-tuple) unit over device buffers; the output buffer
    /// can feed the next unit without a host round trip.
    pub fn execute_unit_b(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[&xla::PjRtBuffer],
    ) -> Result<xla::PjRtBuffer> {
        let mut out = exe
            .execute_b::<&xla::PjRtBuffer>(args)
            .map_err(|e| anyhow!("execute_b: {e:?}"))?;
        Ok(out.swap_remove(0).swap_remove(0))
    }
}

/// Bounds-checked slice of a parameter buffer (truncated/corrupted files
/// must fail loudly, not panic or silently mis-execute).
pub fn slice_checked<'a>(
    buf: &'a [u8],
    offset: usize,
    len: usize,
    what: &str,
) -> Result<&'a [u8]> {
    buf.get(offset..offset + len).ok_or_else(|| {
        anyhow!(
            "{what}: parameter slice [{offset}, {}) exceeds buffer of {} bytes \
             (truncated or corrupted params file?)",
            offset + len,
            buf.len()
        )
    })
}

/// f32 literal from raw little-endian bytes (the zero-copy view into a
/// swapped-in flat parameter buffer).
pub fn literal_f32(shape: &[usize], bytes: &[u8]) -> Result<xla::Literal> {
    let expected: usize = shape.iter().product::<usize>() * 4;
    if bytes.len() != expected {
        return Err(anyhow!(
            "literal bytes {} != shape {:?} ({} bytes)",
            bytes.len(),
            shape,
            expected
        ));
    }
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, shape, bytes)
        .map_err(|e| anyhow!("literal: {e:?}"))
}

/// f32 literal from a slice of values (safe little-endian serialization;
/// the crate forbids `unsafe`, and XLA literals are LE on every target).
pub fn literal_from_f32s(shape: &[usize], vals: &[f32]) -> Result<xla::Literal> {
    let mut bytes = Vec::with_capacity(vals.len() * 4);
    for v in vals {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    literal_f32(shape, &bytes)
}

/// Read an f32 literal back into a Vec.
pub fn literal_to_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
}

/// Build the parameter literals of one unit from its assembled refs over
/// the flat buffer (assembly-by-reference -> runtime hand-off).
pub fn unit_param_literals(
    unit: &UnitMeta,
    refs: &[ParamRef],
    buf: &[u8],
) -> Result<Vec<xla::Literal>> {
    if refs.len() != unit.skeleton.len() {
        return Err(anyhow!(
            "{}: {} refs vs {} skeleton slots",
            unit.name,
            refs.len(),
            unit.skeleton.len()
        ));
    }
    refs.iter()
        .map(|p| literal_f32(&p.shape, param_slice(buf, p)))
        .collect()
}

/// Convenience: run a full artifact model (all units, params read straight
/// from disk, no swapping) — the correctness oracle for the swap paths and
/// the DInf real-execution baseline.
pub struct DirectRunner<'rt> {
    pub rt: &'rt Runtime,
    pub model: ArtifactModel,
    pub batch: usize,
}

impl<'rt> DirectRunner<'rt> {
    pub fn new(rt: &'rt Runtime, model: ArtifactModel, batch: usize) -> Self {
        DirectRunner { rt, model, batch }
    }

    /// Compile all units up front; returns total compile seconds.
    pub fn warmup(&self) -> Result<f64> {
        let t0 = Instant::now();
        for ui in 0..self.model.units.len() {
            self.rt.load_hlo(&self.model.hlo_path(ui, self.batch)?)?;
        }
        Ok(t0.elapsed().as_secs_f64())
    }

    /// Forward `input` (flattened f32s of the model's batch input shape).
    pub fn forward(&self, input: &[f32]) -> Result<Vec<f32>> {
        let mut shape = self.model.in_shape.clone();
        shape[0] = self.batch;
        let mut act = literal_from_f32s(&shape, input)?;
        for (ui, unit) in self.model.units.iter().enumerate() {
            let exe = self.rt.load_hlo(&self.model.hlo_path(ui, self.batch)?)?;
            let buf = std::fs::read(self.model.params_path(ui))
                .with_context(|| format!("params for {}", unit.name))?;
            let params: Vec<xla::Literal> = unit
                .skeleton
                .iter()
                .map(|e| {
                    let s = slice_checked(&buf, e.offset_bytes, e.size_bytes, &unit.name)?;
                    literal_f32(&e.shape, s)
                })
                .collect::<Result<_>>()?;
            act = self.rt.execute_unit(&exe, &act, &params)?;
        }
        literal_to_vec(&act)
    }
}

/// Serving fast path (§Perf): parameters uploaded to device buffers ONCE
/// (the swap-in cost), activations chained on-device between units (no
/// host round trips), non-tuple ref artifacts. This is what a resident
/// (non-swapped) model uses between swap events. Owns a shared handle to
/// the (thread-confined) runtime so the engine's PJRT backend can keep
/// runners cached across requests.
pub struct ResidentModelRunner {
    pub rt: Rc<Runtime>,
    pub model: ArtifactModel,
    pub batch: usize,
    exes: Vec<Rc<xla::PjRtLoadedExecutable>>,
    param_bufs: Vec<Vec<xla::PjRtBuffer>>,
}

impl ResidentModelRunner {
    /// Compile all unit executables (ref variant preferred) and upload
    /// every unit's parameters to the device.
    pub fn new(rt: Rc<Runtime>, model: ArtifactModel, batch: usize) -> Result<Self> {
        use crate::model::artifacts::KernelImpl;
        let mut exes = Vec::with_capacity(model.units.len());
        let mut param_bufs = Vec::with_capacity(model.units.len());
        for (ui, unit) in model.units.iter().enumerate() {
            // Buffer chaining needs the non-tuple ref artifact; fall back
            // is handled by hlo_for_batch_impl.
            let f = unit
                .hlo_for_batch_impl(batch, KernelImpl::Ref)
                .ok_or_else(|| anyhow!("{}: no HLO for batch {batch}", unit.name))?;
            if !f.contains(".ref.") {
                return Err(anyhow!(
                    "{}: resident runner needs the ref artifact variant",
                    unit.name
                ));
            }
            exes.push(rt.load_hlo(&model.dir.join(f))?);
            let buf = std::fs::read(model.params_path(ui))?;
            let bufs: Vec<xla::PjRtBuffer> = unit
                .skeleton
                .iter()
                .map(|e| {
                    let s = slice_checked(&buf, e.offset_bytes, e.size_bytes, &unit.name)?;
                    rt.upload_f32_bytes(s, &e.shape)
                })
                .collect::<Result<_>>()?;
            param_bufs.push(bufs);
        }
        Ok(ResidentModelRunner { rt, model, batch, exes, param_bufs })
    }

    /// Forward with device-resident weights and on-device activations.
    pub fn forward(&self, input: &[f32]) -> Result<Vec<f32>> {
        let mut shape = self.model.in_shape.clone();
        shape[0] = self.batch;
        let mut act = self.rt.upload_f32(input, &shape)?;
        for (ui, exe) in self.exes.iter().enumerate() {
            let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + self.param_bufs[ui].len());
            args.push(&act);
            args.extend(self.param_bufs[ui].iter());
            act = self.rt.execute_unit_b(exe, &args)?;
        }
        let lit = act
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        literal_to_vec(&lit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::artifacts::{artifacts_dir, ArtifactModel};

    fn tiny() -> Option<ArtifactModel> {
        let dir = artifacts_dir().join("tiny_cnn");
        if dir.join("meta.json").exists() {
            Some(ArtifactModel::load(&dir).unwrap())
        } else {
            eprintln!("skipping: no artifacts");
            None
        }
    }

    #[test]
    fn literal_roundtrip() {
        let vals = vec![1.0f32, -2.0, 3.5, 0.0, 9.25, -7.125];
        let lit = literal_from_f32s(&[2, 3], &vals).unwrap();
        assert_eq!(literal_to_vec(&lit).unwrap(), vals);
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(literal_f32(&[4], &[0u8; 12]).is_err());
    }

    #[test]
    fn upload_bytes_single_pass_matches_value_path() {
        let rt = Runtime::cpu().unwrap();
        let vals = vec![1.5f32, -2.25, 0.0, 3.75];
        let mut bytes = Vec::new();
        for v in &vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let a = rt.upload_f32(&vals, &[2, 2]).unwrap().to_literal_sync().unwrap();
        let b = rt.upload_f32_bytes(&bytes, &[2, 2]).unwrap().to_literal_sync().unwrap();
        assert_eq!(a.to_vec::<f32>().unwrap(), b.to_vec::<f32>().unwrap());
        // A deliberately misaligned source takes the conversion path and
        // still lands identical values.
        let mut padded = vec![0u8];
        padded.extend_from_slice(&bytes);
        let c = rt
            .upload_f32_bytes(&padded[1..], &[2, 2])
            .unwrap()
            .to_literal_sync()
            .unwrap();
        assert_eq!(c.to_vec::<f32>().unwrap(), vals);
        // Length validation stays strict.
        assert!(rt.upload_f32_bytes(&bytes[..8], &[2, 2]).is_err());
    }

    #[test]
    fn direct_runner_executes_tiny_cnn() {
        let Some(model) = tiny() else { return };
        let rt = Runtime::cpu().unwrap();
        let runner = DirectRunner::new(&rt, model, 1);
        let n: usize = runner.model.in_shape.iter().skip(1).product();
        let input = vec![0.5f32; n];
        let out = runner.forward(&input).unwrap();
        assert_eq!(out.len(), 10);
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn executable_cache_hits() {
        let Some(model) = tiny() else { return };
        let rt = Runtime::cpu().unwrap();
        let runner = DirectRunner::new(&rt, model, 1);
        runner.warmup().unwrap();
        let n = rt.cached_executables();
        runner.warmup().unwrap();
        assert_eq!(rt.cached_executables(), n, "second warmup must hit cache");
        assert_eq!(n, 6);
    }

    #[test]
    fn resident_runner_matches_direct() {
        let Some(model) = tiny() else { return };
        if model.units[0].hlo_ref_by_batch.is_empty() {
            eprintln!("skipping: artifacts lack ref variants (re-run make artifacts)");
            return;
        }
        let rt = Rc::new(Runtime::cpu().unwrap());
        let n: usize = model.in_shape.iter().skip(1).product();
        let x: Vec<f32> = (0..n).map(|i| (i % 89) as f32 / 89.0).collect();
        let direct = DirectRunner::new(&rt, model.clone(), 1).forward(&x).unwrap();
        let resident = ResidentModelRunner::new(rt.clone(), model, 1).unwrap();
        let fast = resident.forward(&x).unwrap();
        assert_eq!(fast.len(), direct.len());
        for (a, b) in fast.iter().zip(&direct) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn batch_variants_exist() {
        let Some(model) = tiny() else { return };
        let rt = Runtime::cpu().unwrap();
        for b in [1usize, 4, 8] {
            let runner = DirectRunner::new(&rt, model.clone(), b);
            let n: usize = model.in_shape.iter().skip(1).product();
            let out = runner.forward(&vec![0.1f32; n * b]).unwrap();
            assert_eq!(out.len(), 10 * b);
        }
    }
}
