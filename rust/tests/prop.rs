//! Property-based tests over the core invariants.
//!
//! proptest is not in the offline crate universe, so this file carries a
//! small seeded-generator harness (`cases` runs a property over N random
//! cases and reports the failing seed) — same spirit: random structured
//! inputs, explicit invariants.

// A failed unwrap IS the failure signal at this grain; the workspace
// unwrap ban (clippy::unwrap_used) is aimed at production code paths.
#![allow(clippy::unwrap_used)]

use swapnet::config::{DeviceProfile, Processor};
use swapnet::memsim::{MemSim, Space};
use swapnet::model::{LayerInfo, ModelInfo};
use swapnet::pipeline::{
    peak_resident_bytes, peak_resident_bytes_m, residual_objective, residual_objective_spec,
    timeline, timeline_spec, total_stall, total_stall_spec, BlockTimes, PipelineSpec,
};
use swapnet::scheduler::{
    allocate_budgets, allocate_budgets_with_floors, try_allocate_budgets,
    try_allocate_budgets_with_floors, AllocError, ModelDemand,
};
use swapnet::util::json::Json;
use swapnet::util::rng::Rng;

/// Run `prop` over `n` seeded cases; panic with the failing seed.
fn cases<F: FnMut(&mut Rng)>(n: u64, mut prop: F) {
    for seed in 0..n {
        let mut rng = Rng::new(0xC0FFEE ^ seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(e) = result {
            eprintln!("property failed at seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

fn random_times(rng: &mut Rng, max_n: usize) -> Vec<BlockTimes> {
    let n = 1 + rng.below(max_n);
    (0..n)
        .map(|_| BlockTimes {
            t_in: rng.range(0.0, 0.5),
            t_ex: rng.range(0.0, 1.0),
            t_out: rng.range(0.0, 0.2),
        })
        .collect()
}

// ---------------------------------------------------------------------
// pipeline timeline invariants
// ---------------------------------------------------------------------

#[test]
fn prop_timeline_lower_bounds() {
    cases(300, |rng| {
        let times = random_times(rng, 12);
        let tl = timeline(&times);
        let sum_ex: f64 = times.iter().map(|t| t.t_ex).sum();
        let sum_in: f64 = times.iter().map(|t| t.t_in).sum();
        // latency can never beat pure execution + first swap, nor the
        // swap channel's serial capacity.
        assert!(tl.latency() >= sum_ex - 1e-12);
        assert!(tl.latency() >= times[0].t_in + sum_ex - 1e-9);
        assert!(tl.latency() + 1e-9 >= sum_in, "channel capacity");
        assert!(total_stall(&times) >= 0.0);
    });
}

#[test]
fn prop_timeline_monotone_in_costs() {
    cases(200, |rng| {
        let times = random_times(rng, 10);
        let tl = timeline(&times).latency();
        let mut worse = times.clone();
        let i = rng.below(worse.len());
        match rng.below(3) {
            0 => worse[i].t_in += rng.range(0.0, 0.3),
            1 => worse[i].t_ex += rng.range(0.0, 0.3),
            _ => worse[i].t_out += rng.range(0.0, 0.3),
        }
        assert!(
            timeline(&worse).latency() >= tl - 1e-12,
            "increasing any component must not reduce latency"
        );
    });
}

#[test]
fn prop_timeline_schedule_wellformed() {
    cases(300, |rng| {
        let times = random_times(rng, 12);
        let tl = timeline(&times);
        for i in 0..times.len() {
            assert!(tl.swap_end[i] >= tl.swap_start[i]);
            assert!(tl.exec_start[i] + 1e-12 >= tl.swap_end[i]);
            assert!(tl.exec_end[i] >= tl.exec_start[i]);
            if i > 0 {
                assert!(tl.swap_start[i] + 1e-12 >= tl.swap_end[i - 1], "one swap channel");
                assert!(tl.exec_start[i] + 1e-12 >= tl.exec_end[i - 1], "serial exec");
            }
            if i >= 2 {
                assert!(
                    tl.swap_start[i] + 1e-12 >= tl.exec_end[i - 2] + times[i - 2].t_out,
                    "m=2 residency"
                );
            }
        }
    });
}

#[test]
fn prop_residual_equals_timeline() {
    cases(300, |rng| {
        let times = random_times(rng, 12);
        let a = residual_objective(&times);
        let b = timeline(&times).latency();
        assert!((a - b).abs() < 1e-9, "{a} vs {b}");
    });
}

/// The seed-era index-arithmetic m=2 schedule, frozen as a reference:
/// the event-driven simulator must reproduce it bit-for-bit.
fn timeline_m2_reference(times: &[BlockTimes]) -> (Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>) {
    let n = times.len();
    let (mut ss, mut se) = (vec![0.0; n], vec![0.0; n]);
    let (mut es, mut ee) = (vec![0.0; n], vec![0.0; n]);
    for i in 0..n {
        let chan_free = if i == 0 { 0.0 } else { se[i - 1] };
        let mem_free = if i >= 2 { ee[i - 2] + times[i - 2].t_out } else { 0.0 };
        ss[i] = chan_free.max(mem_free);
        se[i] = ss[i] + times[i].t_in;
        let prev_exec = if i == 0 { 0.0 } else { ee[i - 1] };
        es[i] = prev_exec.max(se[i]);
        ee[i] = es[i] + times[i].t_ex;
    }
    (ss, se, es, ee)
}

#[test]
fn prop_event_driven_default_matches_m2_reference_bitwise() {
    // The m=2 parity acceptance: with PipelineSpec::default() the
    // event-driven timeline reproduces the historical schedule exactly
    // (no tolerance) on the random corpus.
    cases(300, |rng| {
        let times = random_times(rng, 12);
        let tl = timeline_spec(&times, &PipelineSpec::default());
        let (ss, se, es, ee) = timeline_m2_reference(&times);
        assert_eq!(tl.swap_start, ss, "swap_start must be bit-identical");
        assert_eq!(tl.swap_end, se);
        assert_eq!(tl.exec_start, es);
        assert_eq!(tl.exec_end, ee);
    });
}

#[test]
fn prop_latency_non_increasing_in_residency_m() {
    // More residency can only relax the memory gate: latency is
    // non-increasing in m (single swap channel). IO-bound workloads
    // (t_in dominating) are covered by the same corpus.
    cases(200, |rng| {
        let times = random_times(rng, 12);
        let mut prev = f64::INFINITY;
        for m in 1..=6 {
            let lat = timeline_spec(&times, &PipelineSpec::with_residency(m)).latency();
            assert!(
                lat <= prev + 1e-12,
                "latency grew with residency: m={m} gives {lat} after {prev}"
            );
            prev = lat;
        }
    });
}

#[test]
fn prop_residual_equals_timeline_for_general_m() {
    cases(200, |rng| {
        let times = random_times(rng, 12);
        let m = 1 + rng.below(5);
        let channels = 1 + rng.below(3);
        let spec = PipelineSpec { residency_m: m, swap_channels: channels };
        let a = residual_objective_spec(&times, &spec);
        let b = timeline_spec(&times, &spec).latency();
        assert!((a - b).abs() < 1e-9, "m={m} c={channels}: {a} vs {b}");
        assert!(total_stall_spec(&times, &spec) >= 0.0);
        // The m=2 wrappers agree with their spec forms.
        let d = PipelineSpec::default();
        assert_eq!(total_stall(&times), total_stall_spec(&times, &d));
        assert_eq!(residual_objective(&times), residual_objective_spec(&times, &d));
    });
}

#[test]
fn prop_timeline_spec_wellformed_for_general_m() {
    cases(200, |rng| {
        let times = random_times(rng, 12);
        let m = 1 + rng.below(5);
        let channels = 1 + rng.below(3);
        let spec = PipelineSpec { residency_m: m, swap_channels: channels };
        let tl = timeline_spec(&times, &spec);
        for i in 0..times.len() {
            assert!(tl.swap_end[i] >= tl.swap_start[i]);
            assert!(tl.exec_start[i] + 1e-12 >= tl.swap_end[i]);
            assert!(tl.exec_end[i] >= tl.exec_start[i]);
            if i > 0 {
                assert!(tl.exec_start[i] + 1e-12 >= tl.exec_end[i - 1], "serial exec");
            }
            if i >= m {
                // Residency m: every block up to i-m has fully left
                // memory before swap i starts.
                for j in 0..=i - m {
                    assert!(
                        tl.swap_start[i] + 1e-12 >= tl.exec_end[j] + times[j].t_out,
                        "residency m={m}: swap {i} began before block {j} left"
                    );
                }
            }
        }
        // Channel capacity: total swap time over `channels` channels.
        let sum_in: f64 = times.iter().map(|t| t.t_in).sum();
        assert!(tl.latency() + 1e-9 >= sum_in / channels as f64, "channel capacity");
    });
}

#[test]
fn prop_peak_residency_m_windows() {
    cases(200, |rng| {
        let n = 1 + rng.below(10);
        let sizes: Vec<u64> = (0..n).map(|_| rng.next_u64() % 1_000_000).collect();
        let total: u64 = sizes.iter().sum();
        let mut prev = peak_resident_bytes_m(&sizes, 1);
        assert_eq!(prev, *sizes.iter().max().unwrap());
        for m in 2..=n + 2 {
            let peak = peak_resident_bytes_m(&sizes, m);
            assert!(peak >= prev, "peak must grow with m");
            assert!(peak <= total);
            prev = peak;
        }
        assert_eq!(peak_resident_bytes_m(&sizes, n), total);
        assert_eq!(peak_resident_bytes(&sizes), peak_resident_bytes_m(&sizes, 2));
    });
}

#[test]
fn prop_peak_residency_bounds() {
    cases(300, |rng| {
        let n = 1 + rng.below(10);
        let sizes: Vec<u64> = (0..n).map(|_| rng.next_u64() % 1_000_000).collect();
        let peak = peak_resident_bytes(&sizes);
        let max1 = *sizes.iter().max().unwrap();
        let total: u64 = sizes.iter().sum();
        assert!(peak >= max1);
        assert!(peak <= total);
        if n >= 2 {
            // peak equals some adjacent pair
            assert!(sizes.windows(2).any(|w| w[0] + w[1] == peak));
        }
    });
}

// ---------------------------------------------------------------------
// model partitioning invariants
// ---------------------------------------------------------------------

fn random_model(rng: &mut Rng) -> ModelInfo {
    let n = 3 + rng.below(40);
    ModelInfo {
        name: "rand".into(),
        family: "rand".into(),
        layers: (0..n)
            .map(|i| LayerInfo {
                name: format!("l{i}"),
                kind: "conv".into(),
                size_bytes: 1 + rng.next_u64() % 40_000_000,
                depth: (rng.below(8)) as u32,
                flops: rng.next_u64() % 2_000_000_000,
                cut_after: rng.f64() < 0.8,
            })
            .collect(),
        accuracy: 90.0,
        processor: if rng.f64() < 0.5 { Processor::Cpu } else { Processor::Gpu },
    }
}

#[test]
fn prop_blocks_conserve_everything() {
    cases(200, |rng| {
        let m = random_model(rng);
        let cuts = m.legal_cut_points();
        if cuts.is_empty() {
            return;
        }
        // random subset of legal cuts
        let mut pts: Vec<usize> = cuts
            .iter()
            .copied()
            .filter(|_| rng.f64() < 0.3)
            .collect();
        pts.sort_unstable();
        pts.dedup();
        let blocks = m.create_blocks(&pts).expect("legal cuts must work");
        assert_eq!(blocks.len(), pts.len() + 1);
        assert_eq!(blocks.iter().map(|b| b.size_bytes).sum::<u64>(), m.size_bytes());
        assert_eq!(blocks.iter().map(|b| b.depth).sum::<u32>(), m.total_depth());
        assert_eq!(blocks.iter().map(|b| b.flops).sum::<u64>(), m.total_flops());
        assert_eq!(
            blocks.iter().map(|b| b.num_layers()).sum::<usize>(),
            m.layers.len()
        );
        // contiguity
        for w in blocks.windows(2) {
            assert_eq!(w[0].layer_hi, w[1].layer_lo);
        }
    });
}

#[test]
fn prop_illegal_cuts_always_rejected() {
    cases(200, |rng| {
        let m = random_model(rng);
        let illegal: Vec<usize> = (1..m.layers.len())
            .filter(|&p| !m.layers[p - 1].cut_after)
            .collect();
        if illegal.is_empty() {
            return;
        }
        let p = illegal[rng.below(illegal.len())];
        assert!(m.create_blocks(&[p]).is_err());
    });
}

// ---------------------------------------------------------------------
// scheduler invariants
// ---------------------------------------------------------------------

#[test]
fn prop_budget_allocation_conserves_and_orders() {
    cases(200, |rng| {
        let n = 2 + rng.below(6);
        let demands: Vec<ModelDemand> = (0..n)
            .map(|i| ModelDemand {
                name: format!("m{i}"),
                mem_bytes: 10_000_000 + rng.next_u64() % 500_000_000,
                latency_s: rng.range(0.05, 2.0),
                urgency: rng.range(0.5, 3.0),
            })
            .collect();
        let total_demand: u64 = demands.iter().map(|d| d.mem_bytes).sum();
        let total = (total_demand as f64 * rng.range(0.3, 0.95)) as u64;
        let alloc = allocate_budgets(&demands, total);
        let sum: u64 = alloc.iter().sum();
        assert!(sum <= total, "over-allocated {sum} > {total}");
        assert!(sum as f64 > total as f64 * 0.98, "left too much on the table");
        assert!(alloc.iter().all(|&a| a > 0));
    });
}

#[test]
fn prop_floors_always_respected_when_feasible() {
    cases(200, |rng| {
        let n = 2 + rng.below(5);
        let demands: Vec<ModelDemand> = (0..n)
            .map(|i| ModelDemand {
                name: format!("m{i}"),
                mem_bytes: 50_000_000 + rng.next_u64() % 400_000_000,
                latency_s: rng.range(0.05, 2.0),
                urgency: 1.0,
            })
            .collect();
        let floors: Vec<u64> = demands
            .iter()
            .map(|d| (d.mem_bytes as f64 * rng.range(0.1, 0.5)) as u64)
            .collect();
        let floor_sum: u64 = floors.iter().sum();
        let total = floor_sum + rng.next_u64() % 500_000_000;
        let alloc = allocate_budgets_with_floors(&demands, &floors, total);
        for (a, f) in alloc.iter().zip(&floors) {
            assert!(a >= f, "floor violated: {a} < {f}");
        }
        assert!(alloc.iter().sum::<u64>() <= total + n as u64, "conservation");
    });
}

#[test]
fn prop_typed_allocation_exact_conservation() {
    // The typed allocator's contract: no rounding drift — under memory
    // pressure the shares sum to exactly the total.
    cases(200, |rng| {
        let n = 2 + rng.below(6);
        let demands: Vec<ModelDemand> = (0..n)
            .map(|i| ModelDemand {
                name: format!("m{i}"),
                mem_bytes: 10_000_000 + rng.next_u64() % 500_000_000,
                latency_s: rng.range(0.05, 2.0),
                urgency: rng.range(0.5, 3.0),
            })
            .collect();
        let total_demand: u64 = demands.iter().map(|d| d.mem_bytes).sum();
        let total = (total_demand as f64 * rng.range(0.3, 0.95)) as u64;
        let alloc = try_allocate_budgets(&demands, total).unwrap();
        assert_eq!(alloc.iter().sum::<u64>(), total, "exact conservation under pressure");
        assert!(alloc.iter().all(|&a| a > 0));
    });
}

#[test]
fn prop_repartitioned_budgets_respect_floors_and_total() {
    // The multi-tenant server's rebalance path: allocate, evict a random
    // model, re-allocate over the survivors. Both partitions must
    // respect every floor and never exceed the total.
    cases(200, |rng| {
        let n = 3 + rng.below(4);
        let mut demands: Vec<ModelDemand> = (0..n)
            .map(|i| ModelDemand {
                name: format!("m{i}"),
                mem_bytes: 50_000_000 + rng.next_u64() % 400_000_000,
                latency_s: rng.range(0.05, 2.0),
                urgency: rng.range(0.5, 3.0),
            })
            .collect();
        let mut floors: Vec<u64> = demands
            .iter()
            .map(|d| (d.mem_bytes as f64 * rng.range(0.1, 0.5)) as u64)
            .collect();
        let floor_sum: u64 = floors.iter().sum();
        let total = floor_sum + rng.next_u64() % 500_000_000;
        let check = |alloc: &[u64], floors: &[u64], demands: &[ModelDemand]| {
            for (a, f) in alloc.iter().zip(floors) {
                assert!(a >= f, "floor violated: {a} < {f}");
            }
            let sum: u64 = alloc.iter().sum();
            assert!(sum <= total, "over-allocated {sum} > {total}");
            let demand_sum: u64 = demands.iter().map(|d| d.mem_bytes).sum();
            if demand_sum > total {
                assert_eq!(sum, total, "pressure must consume the whole budget");
            }
        };
        let before = try_allocate_budgets_with_floors(&demands, &floors, total).unwrap();
        check(&before, &floors, &demands);
        // Evict one model; the survivors re-partition.
        let kill = rng.below(n);
        demands.remove(kill);
        floors.remove(kill);
        let after = try_allocate_budgets_with_floors(&demands, &floors, total).unwrap();
        check(&after, &floors, &demands);
    });
}

#[test]
fn prop_typed_allocation_degenerate_fleets_are_errors() {
    cases(100, |rng| {
        // Zero-demand fleets are typed errors, never silent zeros.
        let n = 1 + rng.below(4);
        let demands: Vec<ModelDemand> = (0..n)
            .map(|i| ModelDemand {
                name: format!("m{i}"),
                mem_bytes: 0,
                latency_s: rng.range(0.0, 1.0),
                urgency: 1.0,
            })
            .collect();
        assert_eq!(
            try_allocate_budgets(&demands, 1 + rng.next_u64() % 1_000_000),
            Err(AllocError::ZeroDemand)
        );
        // A floor beyond the total is a typed error naming the model.
        let d = vec![ModelDemand {
            name: "big".into(),
            mem_bytes: 100 + rng.next_u64() % 1_000_000,
            latency_s: 1.0,
            urgency: 1.0,
        }];
        let total = 1000 + rng.next_u64() % 1_000_000;
        let err = try_allocate_budgets_with_floors(&d, &[total + 1], total).unwrap_err();
        assert!(matches!(err, AllocError::FloorExceedsTotal { .. }), "{err}");
    });
}

// ---------------------------------------------------------------------
// memory simulator invariants
// ---------------------------------------------------------------------

#[test]
fn prop_memsim_accounting_consistent() {
    cases(150, |rng| {
        let mut mem = MemSim::new(u64::MAX);
        let mut live: Vec<(swapnet::memsim::AllocId, u64)> = Vec::new();
        let mut expect_cur = 0u64;
        let mut expect_peak = 0u64;
        for _ in 0..200 {
            if live.is_empty() || rng.f64() < 0.6 {
                let sz = 1 + rng.next_u64() % 10_000_000;
                let space = match rng.below(4) {
                    0 => Space::Cpu,
                    1 => Space::Gpu,
                    2 => Space::Unified,
                    _ => Space::PageCache,
                };
                let id = mem.alloc("t", space, sz);
                live.push((id, sz));
                expect_cur += sz;
                expect_peak = expect_peak.max(expect_cur);
            } else {
                let i = rng.below(live.len());
                let (id, sz) = live.swap_remove(i);
                mem.free(id).expect("live id");
                expect_cur -= sz;
            }
            assert_eq!(mem.current(), expect_cur);
            assert_eq!(mem.peak(), expect_peak);
        }
        for (id, _) in live.drain(..) {
            mem.free(id).expect("live id");
        }
        assert_eq!(mem.current(), 0);
        assert_eq!(mem.live_allocs(), 0);
    });
}

#[test]
fn prop_pinned_bytes_never_evicted_and_never_double_counted() {
    // The pinned class is a persistent residency ledger: random
    // interleavings of pinned allocs/grows with ordinary swap traffic
    // must (a) keep every live pin's bytes visible until freed, (b)
    // account pinned bytes in Space::Pinned only — swap spaces' peaks
    // stay truthful, untouched by KV load.
    cases(150, |rng| {
        let total = 500_000_000u64;
        let mut mem = MemSim::new(total);
        let mut pins: Vec<(swapnet::memsim::AllocId, u64)> = Vec::new();
        let mut expect_pinned = 0u64;
        let mut swap_peak_seen = 0u64;
        for _ in 0..150 {
            match rng.below(4) {
                0 => {
                    let sz = 1 + rng.next_u64() % 5_000_000;
                    if let Ok(id) = mem.try_alloc_pinned("kv", sz) {
                        pins.push((id, sz));
                        expect_pinned += sz;
                    }
                }
                1 if !pins.is_empty() => {
                    let i = rng.below(pins.len());
                    let delta = 1 + rng.next_u64() % 1_000_000;
                    if mem.try_grow_pinned(pins[i].0, delta).is_ok() {
                        pins[i].1 += delta;
                        expect_pinned += delta;
                    }
                }
                2 if !pins.is_empty() => {
                    let i = rng.below(pins.len());
                    let (id, sz) = pins.swap_remove(i);
                    mem.free(id).expect("live pin");
                    expect_pinned -= sz;
                }
                _ => {
                    // Transient swap traffic in an ordinary space.
                    let sz = 1 + rng.next_u64() % 5_000_000;
                    let id = mem.alloc("sweep", Space::Unified, sz);
                    swap_peak_seen = swap_peak_seen.max(sz);
                    mem.free(id).expect("live id");
                }
            }
            assert_eq!(mem.pinned_bytes(), expect_pinned, "pinned ledger drifted");
            assert_eq!(mem.current_in(Space::Pinned), expect_pinned);
            for (id, sz) in &pins {
                assert_eq!(mem.size_of(*id), Some(*sz), "a live pin was evicted");
            }
            assert!(
                mem.peak_in(Space::Unified) <= swap_peak_seen,
                "pinned bytes leaked into a swap space's peak: {} > {}",
                mem.peak_in(Space::Unified),
                swap_peak_seen
            );
        }
        // The overall peak counts pinned + swap together exactly once.
        assert!(mem.peak() <= expect_pinned.max(mem.peak_in(Space::Pinned)) + swap_peak_seen);
    });
}

#[test]
fn prop_pinned_growth_beyond_budget_fails_gracefully() {
    // KV growth alone hitting the budget must surface as a typed
    // AllocError — never a panic, never an overcommit (oom_events is
    // the ordinary spaces' overcommit counter and stays 0).
    cases(150, |rng| {
        let total = 1 + rng.next_u64() % 50_000_000;
        let mut mem = MemSim::new(total);
        let first = 1 + rng.next_u64() % total;
        let id = mem.try_alloc_pinned("kv", first).expect("first pin fits");
        let step = 100_000 + rng.next_u64() % 1_000_000;
        let mut pinned = first;
        loop {
            match mem.try_grow_pinned(id, step) {
                Ok(()) => {
                    pinned += step;
                    assert!(pinned <= total);
                }
                Err(e) => {
                    assert_eq!(e.requested, step);
                    assert_eq!(e.available, total - pinned, "{e}");
                    assert!(e.requested > e.available);
                    break;
                }
            }
        }
        // The refused growth changed nothing.
        assert_eq!(mem.pinned_bytes(), pinned);
        assert_eq!(mem.size_of(id), Some(pinned));
        assert_eq!(mem.oom_events, 0, "the checked path never overcommits");
        assert!(mem.current() <= total);
        // An oversized fresh pin is refused the same way.
        let err = mem.try_alloc_pinned("kv2", total).unwrap_err();
        assert_eq!(err.available, total - pinned);
        mem.free(id).expect("live pin");
        assert_eq!(mem.pinned_bytes(), 0);
    });
}

// ---------------------------------------------------------------------
// JSON roundtrip
// ---------------------------------------------------------------------

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    if depth == 0 {
        return match rng.below(4) {
            0 => Json::Null,
            1 => Json::Bool(rng.f64() < 0.5),
            2 => Json::Num((rng.next_u64() % 1_000_000) as f64 / 8.0),
            _ => Json::Str(format!("s{}", rng.next_u64() % 1000)),
        };
    }
    match rng.below(6) {
        0 => Json::Null,
        1 => Json::Bool(true),
        2 => Json::Num(-(rng.f64() * 1e6).round() / 16.0),
        3 => Json::Str("αβ\"\\\n esc".into()),
        4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => {
            let mut m = std::collections::BTreeMap::new();
            for i in 0..rng.below(5) {
                m.insert(format!("k{i}"), random_json(rng, depth - 1));
            }
            Json::Obj(m)
        }
    }
}

#[test]
fn prop_json_roundtrip() {
    cases(300, |rng| {
        let v = random_json(rng, 4);
        let s = v.to_string();
        let v2 = Json::parse(&s).expect("serializer output must reparse");
        assert_eq!(v, v2, "roundtrip mismatch for {s}");
    });
}

// ---------------------------------------------------------------------
// swap-path invariants
// ---------------------------------------------------------------------

#[test]
fn prop_zero_copy_never_exceeds_block_size() {
    use swapnet::model::BlockInfo;
    use swapnet::storage::Storage;
    use swapnet::swap::{SwapController, SwapMode};
    cases(100, |rng| {
        let prof = DeviceProfile::jetson_nx();
        let mut st = Storage::new(256_000_000);
        let mut mem = MemSim::new(u64::MAX);
        let ctl = SwapController::new(SwapMode::ZeroCopy, "p");
        let sz = 1_000_000 + rng.next_u64() % 200_000_000;
        let b = BlockInfo {
            index: 0,
            layer_lo: 0,
            layer_hi: 1,
            size_bytes: sz,
            depth: 1 + rng.below(100) as u32,
            flops: 1,
        };
        let proc = if rng.f64() < 0.5 { Processor::Cpu } else { Processor::Gpu };
        let rb = ctl.swap_in_sim(&b, rng.next_u64(), proc, &mut st, &mut mem, &prof);
        assert_eq!(mem.current(), sz, "zero-copy = exactly one copy");
        let rep = ctl.swap_out(rb, &mut mem, &prof);
        assert_eq!(rep.freed_bytes, sz);
        assert_eq!(mem.current(), 0);
    });
}

#[test]
fn prop_standard_path_at_least_doubles() {
    use swapnet::model::BlockInfo;
    use swapnet::storage::Storage;
    use swapnet::swap::{SwapController, SwapMode};
    cases(100, |rng| {
        let prof = DeviceProfile::jetson_nx();
        let mut st = Storage::new(1_000_000_000);
        let mut mem = MemSim::new(u64::MAX);
        let ctl = SwapController::new(SwapMode::Standard, "p");
        let sz = 1_000_000 + rng.next_u64() % 100_000_000;
        let b = BlockInfo {
            index: 0,
            layer_lo: 0,
            layer_hi: 1,
            size_bytes: sz,
            depth: 4,
            flops: 1,
        };
        let proc = if rng.f64() < 0.5 { Processor::Cpu } else { Processor::Gpu };
        let factor = if proc == Processor::Gpu { 3 } else { 2 };
        let _rb = ctl.swap_in_sim(&b, rng.next_u64(), proc, &mut st, &mut mem, &prof);
        // page-cache copy is page-rounded; allow one page of slack.
        assert!(
            mem.current() + 4096 >= factor * sz,
            "standard path must keep {factor} copies of {sz}, had {}",
            mem.current()
        );
    });
}

// ---------------------------------------------------------------------
// planner DP invariants (exactness vs enumeration, dominance vs the
// frozen beam search it replaced)
// ---------------------------------------------------------------------

use swapnet::delay::DelayModel;
use swapnet::model::families;
use swapnet::planner::{dp, AnalyticCosts, CostProvider};
use swapnet::scheduler::partition::{self, Row};

fn nx_dm() -> DelayModel {
    DelayModel::from_profile(&DeviceProfile::jetson_nx())
}

/// Canonical selection shared by the oracle and the DP comparison:
/// minimal latency, then minimal memory.
fn canonical_best(rows: &[Row]) -> Option<&Row> {
    rows.iter().min_by(|a, b| {
        a.predicted_latency_s
            .total_cmp(&b.predicted_latency_s)
            .then(a.max_mem_bytes.cmp(&b.max_mem_bytes))
    })
}

#[test]
fn prop_dp_best_row_identical_to_exhaustive_enumeration() {
    // The tentpole exactness claim: for every n <= 3 fixture the DP's
    // best row is a latency-minimal row of the full enumeration with
    // bitwise-equal (mem, latency), and its points appear verbatim in
    // the enumeration at exactly that (mem, latency).
    cases(60, |rng| {
        let m = random_model(rng);
        let dm = nx_dm();
        let costs = AnalyticCosts::new(dm.clone());
        let spec = if rng.f64() < 0.5 {
            PipelineSpec::default()
        } else {
            PipelineSpec::with_residency(1 + rng.below(3))
        };
        for n in 2..=3usize {
            if m.legal_cut_points().len() < n - 1 {
                continue;
            }
            let all = partition::enumerate_rows(&m, n, &dm, &spec);
            let front = dp::frontier(&m, n, &costs, &spec);
            let (Some(want), Some(got)) =
                (canonical_best(&all), front.best_within(u64::MAX))
            else {
                assert!(all.is_empty() && front.rows.is_empty());
                continue;
            };
            assert_eq!(got.predicted_latency_s, want.predicted_latency_s, "n={n}");
            assert_eq!(got.max_mem_bytes, want.max_mem_bytes, "n={n}");
            assert!(
                all.iter().any(|r| r.points == got.points
                    && r.predicted_latency_s == got.predicted_latency_s
                    && r.max_mem_bytes == got.max_mem_bytes),
                "DP points {:?} must appear verbatim in the enumeration",
                got.points
            );
            // Budget-gated probes agree too (bitwise).
            let lo = all.iter().map(|r| r.max_mem_bytes).min().unwrap();
            let hi = all.iter().map(|r| r.max_mem_bytes).max().unwrap();
            let budget = lo + rng.next_u64() % (hi - lo + 1);
            let feasible: Vec<Row> = all
                .iter()
                .filter(|r| r.max_mem_bytes <= budget)
                .cloned()
                .collect();
            match (canonical_best(&feasible), front.best_within(budget)) {
                (Some(w), Some(g)) => {
                    assert_eq!(g.predicted_latency_s, w.predicted_latency_s);
                    assert_eq!(g.max_mem_bytes, w.max_mem_bytes);
                }
                (None, None) => {}
                (w, g) => panic!("feasibility mismatch at {budget}: {w:?} vs {g:?}"),
            }
        }
    });
}

/// A compact random model for the deeper-n DP properties (keeps the
/// debug-mode state space small while still exercising every code
/// path: uneven sizes, forbidden cuts, both processors).
fn small_random_model(rng: &mut Rng) -> ModelInfo {
    let mut m = random_model(rng);
    m.layers.truncate(4 + rng.below(10));
    m
}

#[test]
fn prop_dp_rows_bitwise_equal_batch_evaluation() {
    // Every frontier row's (mem, latency) must be exactly what
    // `evaluate_spec` computes for its points — the incremental
    // timeline performs the same float ops in the same order.
    cases(40, |rng| {
        let m = small_random_model(rng);
        let dm = nx_dm();
        let costs = AnalyticCosts::new(dm.clone());
        let spec = PipelineSpec {
            residency_m: 1 + rng.below(4),
            swap_channels: 1 + rng.below(2),
        };
        let n = 2 + rng.below(5);
        if m.legal_cut_points().len() < n - 1 {
            return;
        }
        let front = dp::frontier(&m, n, &costs, &spec);
        for r in &front.rows {
            let (mem, lat) = partition::evaluate_spec(&m, &r.points, &dm, &spec)
                .expect("frontier points are legal");
            assert_eq!(r.max_mem_bytes, mem, "{:?}", r.points);
            assert_eq!(r.predicted_latency_s, lat, "{:?}", r.points);
        }
    });
}

/// Frozen copy of the beam search the DP replaced (PR 5), kept as the
/// reference its "never worse" guarantee is tested against — the same
/// pattern as PR 3's frozen m=2 timeline.
mod frozen_beam {
    use std::collections::BTreeMap;
    use swapnet::delay::DelayModel;
    use swapnet::model::ModelInfo;
    use swapnet::pipeline::{PipelineSpec, SwapVariant};
    use swapnet::scheduler::partition::{evaluate_spec, Row};

    pub fn heuristic_rows(
        model: &ModelInfo,
        n: usize,
        dm: &DelayModel,
        spec: &PipelineSpec,
    ) -> Vec<Row> {
        let cuts = model.legal_cut_points();
        let k = n - 1;
        if cuts.len() < k {
            return vec![];
        }
        let mut seen: BTreeMap<Vec<usize>, (u64, f64)> = BTreeMap::new();
        let record =
            |pts: &[usize], seen: &mut BTreeMap<Vec<usize>, (u64, f64)>| -> Option<(u64, f64)> {
                if let Some(&v) = seen.get(pts) {
                    return Some(v);
                }
                let v = evaluate_spec(model, pts, dm, spec)?;
                seen.insert(pts.to_vec(), v);
                Some(v)
            };

        let total = model.size_bytes();
        let prefix: Vec<u64> = {
            let mut acc = 0;
            model
                .layers
                .iter()
                .map(|l| {
                    acc += l.size_bytes;
                    acc
                })
                .collect()
        };
        let mut seeds: Vec<Vec<usize>> = Vec::new();
        for first_frac in [0.1, 0.25, 0.5, 1.0] {
            let first = (total as f64 / n as f64) * first_frac;
            let rest = (total as f64 - first) / (n - 1) as f64;
            let mut targets = Vec::with_capacity(k);
            let mut t = first;
            for _ in 0..k {
                targets.push(t);
                t += rest;
            }
            let mut pts = Vec::with_capacity(k);
            let mut lo = 0usize;
            for tgt in targets {
                let mut best = None;
                for (ci, &c) in cuts.iter().enumerate().skip(lo) {
                    if cuts.len() - ci < k - pts.len() {
                        break;
                    }
                    let d = (prefix[c - 1] as f64 - tgt).abs();
                    match best {
                        None => best = Some((ci, d)),
                        Some((_, bd)) if d < bd => best = Some((ci, d)),
                        _ => {}
                    }
                }
                if let Some((ci, _)) = best {
                    pts.push(cuts[ci]);
                    lo = ci + 1;
                }
            }
            if pts.len() == k {
                seeds.push(pts);
            }
        }

        let pos_of = |c: usize| cuts.binary_search(&c).ok();
        for seed in seeds {
            for minimize_peak in [true, false] {
                let mut cur = seed.clone();
                let Some(mut cur_v) = record(&cur, &mut seen) else { continue };
                loop {
                    let mut improved = false;
                    for j in 0..k {
                        let Some(pj) = pos_of(cur[j]) else { continue };
                        for step in [-3i64, -2, -1, 1, 2, 3] {
                            let np = pj as i64 + step;
                            if np < 0 || np as usize >= cuts.len() {
                                continue;
                            }
                            let cand_cut = cuts[np as usize];
                            if (j > 0 && cand_cut <= cur[j - 1])
                                || (j + 1 < k && cand_cut >= cur[j + 1])
                            {
                                continue;
                            }
                            let mut cand = cur.clone();
                            cand[j] = cand_cut;
                            if let Some(v) = record(&cand, &mut seen) {
                                let better = if minimize_peak {
                                    v.0 < cur_v.0 || (v.0 == cur_v.0 && v.1 < cur_v.1)
                                } else {
                                    v.1 < cur_v.1
                                };
                                if better {
                                    cur = cand;
                                    cur_v = v;
                                    improved = true;
                                }
                            }
                        }
                    }
                    if !improved {
                        break;
                    }
                }
            }
        }

        seen.into_iter()
            .map(|(points, (mem, lat))| Row {
                variants: vec![SwapVariant::Plain; points.len() + 1],
                points,
                max_mem_bytes: mem,
                predicted_latency_s: lat,
            })
            .collect()
    }
}

#[test]
fn dp_never_worse_than_frozen_beam_on_model_families() {
    // The replacement guarantee for n > 3: the exact DP's best row is
    // never worse than the old beam search's, on every model family and
    // n in 4..=8 (unconstrained and at the beam best's own budget).
    let dm = nx_dm();
    let costs = AnalyticCosts::new(dm.clone());
    let spec = PipelineSpec::default();
    for m in [families::vgg19(), families::resnet101(), families::yolov3(), families::fcn()] {
        for n in [4usize, 6, 8] {
            if m.legal_cut_points().len() < n - 1 {
                continue;
            }
            let beam = frozen_beam::heuristic_rows(&m, n, &dm, &spec);
            let front = dp::frontier(&m, n, &costs, &spec);
            let Some(beam_best) = canonical_best(&beam) else { continue };
            let dp_best = front.best_within(u64::MAX).expect("beam found a row, DP must too");
            assert!(
                dp_best.predicted_latency_s <= beam_best.predicted_latency_s + 1e-12,
                "{} n={n}: DP {} worse than beam {}",
                m.name,
                dp_best.predicted_latency_s,
                beam_best.predicted_latency_s
            );
            // And under the beam best's own memory budget.
            let gated = front
                .best_within(beam_best.max_mem_bytes)
                .expect("beam row is feasible at its own budget");
            assert!(gated.predicted_latency_s <= beam_best.predicted_latency_s + 1e-12);
        }
    }
}

// ---------------------------------------------------------------------
// serving-reactor invariants
// ---------------------------------------------------------------------

mod reactor_props {
    use swapnet::config::MB;
    use swapnet::engine::Engine;
    use swapnet::model::families;
    use swapnet::server::multi::{MultiTenantConfig, MultiTenantServer};
    use swapnet::server::{AdmissionPolicy, LoadGen};

    use super::cases;

    fn fleet_server(cfg: MultiTenantConfig) -> MultiTenantServer {
        let mut server = MultiTenantServer::new(Engine::builder().build(), cfg);
        for m in [families::resnet101(), families::yolov3(), families::fcn()] {
            server.register(m, 1.0).expect("trio partitions under the budget");
        }
        server
    }

    #[test]
    fn prop_oversubscribed_reactor_sheds_and_never_violates_the_ledger() {
        // 10x+ oversubscription: ~200 req/s offered against a fleet
        // whose batch windows run for seconds. Whatever the admission
        // policy decides, overload must resolve through shedding or
        // rejection — never through the MemSim ledger.
        cases(6, |rng| {
            let mut cfg = MultiTenantConfig::new(300 * MB);
            cfg.policy =
                if rng.f64() < 0.5 { AdmissionPolicy::Fifo } else { AdmissionPolicy::Urgency };
            cfg.queue_cap = 2 + rng.below(6);
            cfg.global_cap = cfg.queue_cap * 2 + rng.below(8);
            cfg.max_batch = 1 + rng.below(8);
            let mut server = fleet_server(cfg);
            let n = 100;
            let load = LoadGen::poisson(3, n, 200.0, rng.next_u64());
            let rep = server.serve_load(&load).unwrap();
            assert_eq!(rep.resolved(), n, "every arrival resolves exactly once");
            assert!(rep.served > 0, "the admitted head of queue is served");
            assert!(
                rep.shed + rep.rejected > 0,
                "10x oversubscription must shed through admission"
            );
            assert_eq!(rep.oom_events, 0, "overload never reaches the ledger");
            assert!(rep.within_budget(), "peak {} vs {}", rep.peak_bytes, rep.total_budget);
            assert!(rep.peak_bytes > 0);
            assert_eq!(rep.hist.len(), rep.served as u64);
            if rep.per_model.values().any(|m| m.shed > 0) {
                assert_eq!(
                    rep.shed,
                    rep.per_model.values().map(|m| m.shed).sum::<usize>(),
                    "fleet shed total matches the per-model decomposition"
                );
            }
        });
    }

    #[test]
    fn prop_doubling_arrival_rate_never_decreases_throughput() {
        // Work conservation: the same 60 requests offered twice as fast
        // arrive strictly earlier (the exp draws scale by exactly 1/rate
        // for a fixed seed), batch at least as densely, and finish no
        // later — so served/makespan throughput is monotone in the
        // offered rate across the under- to over-subscribed range.
        cases(3, |rng| {
            let seed = rng.next_u64();
            let mut cfg = MultiTenantConfig::new(300 * MB);
            cfg.policy = AdmissionPolicy::Urgency;
            // Caps sized so nothing sheds: served counts stay equal and
            // the comparison is purely about completion times.
            cfg.queue_cap = 64;
            cfg.global_cap = 256;
            let mut server = fleet_server(cfg);
            let n = 60;
            let mut prev = 0.0f64;
            for rate in [5.0, 10.0, 20.0, 40.0, 80.0] {
                let rep =
                    server.serve_load(&LoadGen::poisson(3, n, rate, seed)).unwrap();
                assert_eq!(rep.served, n, "caps admit everything at {rate} Hz");
                assert!(rep.within_budget());
                let thr = rep.served as f64 / rep.makespan_s.max(1e-9);
                assert!(
                    thr >= prev * 0.999,
                    "throughput fell from {prev:.3} to {thr:.3} req/s when the \
                     rate doubled to {rate} Hz"
                );
                prev = thr;
            }
        });
    }
}

#[test]
fn prop_planner_cost_provider_parity() {
    // AnalyticCosts::block_times is bitwise the DelayModel triple.
    cases(40, |rng| {
        let m = random_model(rng);
        let dm = nx_dm();
        let costs = AnalyticCosts::new(dm.clone());
        let blocks = m.create_blocks(&[]).unwrap();
        for b in &blocks {
            let t = costs.block_times(b, m.processor);
            assert_eq!(t.t_in, dm.t_in(b));
            assert_eq!(t.t_ex, dm.t_ex(b, m.processor));
            assert_eq!(t.t_out, dm.t_out(b));
        }
    });
}
