//! Multi-tenant serving runtime integration tests: dynamic Eq. 1
//! re-partition on register/evict, admission control under overload,
//! resident-window batching, per-request traces, and — the headline
//! claim — a fleet whose combined footprint is well beyond the memory
//! budget serving a mixed stream with zero budget violations, asserted
//! via the shared MemSim residency ledger. Every drive mode funnels into
//! the same event-driven reactor: virtual-clock streams here, and live
//! client threads whose submissions are wall-stamped and replayed
//! (concurrent mode).

// A failed unwrap IS the failure signal at this grain; the workspace
// unwrap ban (clippy::unwrap_used) is aimed at production code paths.
#![allow(clippy::unwrap_used)]

use swapnet::config::{DeviceProfile, MB};
use swapnet::delay::DelayModel;
use swapnet::engine::Engine;
use swapnet::model::families;
use swapnet::scheduler::ModelDemand;
use swapnet::server::multi::{poisson_stream, MultiTenantConfig, MultiTenantServer, Request};
use swapnet::server::AdmissionPolicy;

fn trio() -> Vec<swapnet::model::ModelInfo> {
    vec![families::resnet101(), families::yolov3(), families::fcn()]
}

fn server_300mb(policy: AdmissionPolicy) -> MultiTenantServer {
    let mut cfg = MultiTenantConfig::new(300 * MB);
    cfg.policy = policy;
    cfg.queue_cap = 32;
    cfg.global_cap = 96;
    MultiTenantServer::new(Engine::builder().build(), cfg)
}

#[test]
fn mixed_stream_beyond_budget_serves_with_zero_violations() {
    // The acceptance demo: 3 models whose combined footprint is >=2x the
    // budget serve a mixed stream with zero budget violations.
    let mut server = server_300mb(AdmissionPolicy::Urgency);
    for m in trio() {
        server.register(m, 1.0).unwrap();
    }
    assert!(
        server.fleet_bytes() >= 2 * 300 * MB,
        "fleet {} must be >=2x the 300 MB budget",
        server.fleet_bytes()
    );
    let stream = poisson_stream(3, 60, 30.0, 7);
    let rep = server.serve(&stream).unwrap();
    assert_eq!(rep.resolved(), 60);
    assert_eq!(rep.served, 60, "caps sized to admit the whole stream");
    assert!(rep.within_budget(), "peak {} vs {}", rep.peak_bytes, rep.total_budget);
    assert!(rep.peak_bytes > 0);
    assert_eq!(rep.oom_events, 0);
    // 30 Hz arrivals against ~0.5 s model latencies force batching.
    assert!(rep.batches < rep.served, "{} batches", rep.batches);
    assert!(rep.per_model.values().any(|s| s.mean_batch() > 1.0));
    // Traces decompose every request.
    assert_eq!(rep.traces.len(), rep.served);
    for tr in &rep.traces {
        assert!(tr.e2e_s > 0.0, "{tr:?}");
        assert!(tr.compute_s > 0.0);
        assert!(tr.swap_s > 0.0, "every block pass swaps in: {tr:?}");
        assert!(tr.queue_s >= -1e-9);
        assert!(tr.batch >= 1);
        assert!(tr.e2e_s + 1e-9 >= tr.queue_s + tr.compute_s, "overlap bound: {tr:?}");
    }
    let per_model_served: usize = rep.per_model.values().map(|s| s.served).sum();
    assert_eq!(per_model_served, rep.served);

    // A second run on the same server starts a fresh serving clock —
    // tenants must not inherit the previous run's busy windows.
    let rep2 = server.serve(&poisson_stream(3, 20, 30.0, 8)).unwrap();
    assert_eq!(rep2.served, 20, "repeat serve must dispatch again");
    assert!(rep2.within_budget());
}

#[test]
fn residency_three_serving_stays_within_budget() {
    // The memory-vs-latency knob, end to end: an m=3 engine keeps three
    // consecutive blocks resident per tenant, so floors, Eq. 1 shares,
    // schedules, and resident windows all shift — and the shared ledger
    // must still prove the fleet never exceeds the budget.
    let mut cfg = MultiTenantConfig::new(300 * MB);
    cfg.policy = AdmissionPolicy::Urgency;
    cfg.queue_cap = 32;
    cfg.global_cap = 96;
    let mut server = MultiTenantServer::new(Engine::builder().pipeline_m(3).build(), cfg);
    for m in trio() {
        server.register(m, 1.0).unwrap();
    }
    let budget_sum: u64 = server.budgets().iter().map(|(_, b, _)| *b).sum();
    assert!(budget_sum <= 300 * MB, "Eq. 1 shares must fit: {budget_sum}");
    for (name, _, blocks) in server.budgets() {
        assert!(blocks >= 2, "{name}: beyond-budget tenant must swap ({blocks} blocks)");
    }
    let rep = server.serve(&poisson_stream(3, 30, 20.0, 11)).unwrap();
    assert_eq!(rep.resolved(), 30);
    assert!(rep.within_budget(), "peak {} vs {}", rep.peak_bytes, rep.total_budget);
    assert_eq!(rep.oom_events, 0);
}

#[test]
fn register_and_evict_repartition_the_fleet_budget() {
    let mut server = server_300mb(AdmissionPolicy::Urgency);
    let _r = server.register(families::resnet101(), 1.0).unwrap();
    let solo = server.budgets();
    assert_eq!(solo.len(), 1);
    assert_eq!(solo[0].1, families::resnet101().size_bytes(), "alone and fitting -> full demand");

    let y = server.register(families::yolov3(), 1.0).unwrap();
    server.register(families::fcn(), 1.0).unwrap();
    let three: Vec<u64> = server.budgets().iter().map(|(_, b, _)| *b).collect();
    assert_eq!(three.len(), 3);
    assert!(three.iter().sum::<u64>() <= 300 * MB, "Eq. 1 conserves the fleet budget");
    assert!(three[0] < solo[0].1, "new tenants shrink the incumbent's share");

    // Evict one model at runtime: survivors re-expand into the freed
    // budget and re-block under their larger shares.
    let shed = server.evict(y).unwrap();
    assert_eq!(shed, 0, "idle eviction sheds nothing");
    assert_eq!(server.registered(), 2);
    let after = server.budgets();
    assert_eq!(after.len(), 2);
    let resnet_after = after.iter().find(|(n, _, _)| n == "resnet101").unwrap().1;
    assert!(resnet_after > three[0], "{resnet_after} vs {}", three[0]);
    for (name, budget, _) in &after {
        assert!(*budget > 0, "{name}");
    }

    // The reshuffled fleet still serves (tenant ids stay stable).
    let stream = vec![
        Request { tenant: 0, arrival_s: 0.0, deadline_s: None },
        Request { tenant: 2, arrival_s: 0.1, deadline_s: None },
    ];
    let rep = server.serve(&stream).unwrap();
    assert_eq!(rep.served, 2);

    // Requests to the evicted tenant are cleanly rejected.
    let stream = vec![Request { tenant: y, arrival_s: 0.0, deadline_s: None }];
    let rep = server.serve(&stream).unwrap();
    assert_eq!(rep.rejected, 1);
    assert_eq!(rep.served, 0);

    // Double eviction is a clean error.
    assert!(server.evict(y).is_err());
}

#[test]
fn urgency_overload_sheds_lowest_score_model_first() {
    // Identify the lowest-performance-score family (paper §6.2.2: PS =
    // u * latency / memory) — the policy's designated overload victim.
    let dm = DelayModel::from_profile(&DeviceProfile::jetson_nx());
    let fams = trio();
    let min_name = fams
        .iter()
        .min_by(|a, b| {
            ModelDemand::from_model(a, &dm, 1.0)
                .performance_score()
                .total_cmp(&ModelDemand::from_model(b, &dm, 1.0).performance_score())
        })
        .unwrap()
        .name
        .clone();

    let mut cfg = MultiTenantConfig::new(300 * MB);
    cfg.policy = AdmissionPolicy::Urgency;
    cfg.queue_cap = 4;
    cfg.global_cap = 6;
    let mut server = MultiTenantServer::new(Engine::builder().build(), cfg);
    for m in fams {
        server.register(m, 1.0).unwrap();
    }
    // A near-instant round-robin burst overwhelms the bounded queues.
    let stream: Vec<Request> = (0..40)
        .map(|i| Request { tenant: i % 3, arrival_s: 1e-4 * i as f64, deadline_s: None })
        .collect();
    let rep = server.serve(&stream).unwrap();
    assert_eq!(rep.resolved(), 40);
    assert!(rep.shed > 0, "overload must shed");
    assert!(rep.rejected > 0, "the lowest-score model's own arrivals get refused");
    let min_shed = rep.per_model.get(&min_name).map(|s| s.shed).unwrap_or(0);
    assert!(min_shed > 0, "lowest-score model {min_name} must shed first");
    for (name, st) in &rep.per_model {
        if name != &min_name {
            assert!(
                min_shed >= st.shed,
                "{min_name} shed {min_shed} < {name} shed {}",
                st.shed
            );
        }
    }
    assert!(rep.within_budget(), "shedding protects the budget");
}

#[test]
fn fifo_overload_rejects_newcomers_instead_of_shedding() {
    let mut cfg = MultiTenantConfig::new(300 * MB);
    cfg.policy = AdmissionPolicy::Fifo;
    cfg.queue_cap = 4;
    cfg.global_cap = 6;
    let mut server = MultiTenantServer::new(Engine::builder().build(), cfg);
    for m in trio() {
        server.register(m, 1.0).unwrap();
    }
    let stream: Vec<Request> = (0..40)
        .map(|i| Request { tenant: i % 3, arrival_s: 1e-4 * i as f64, deadline_s: None })
        .collect();
    let rep = server.serve(&stream).unwrap();
    assert_eq!(rep.resolved(), 40);
    assert_eq!(rep.shed, 0, "FIFO never displaces queued work");
    assert!(rep.rejected > 0, "FIFO refuses the overflow");
    assert!(rep.within_budget());
}

#[test]
fn deadline_policy_rejects_infeasible_and_serves_the_rest() {
    let mut cfg = MultiTenantConfig::new(300 * MB);
    cfg.policy = AdmissionPolicy::Deadline;
    let mut server = MultiTenantServer::new(Engine::builder().build(), cfg);
    let t = server.register(families::resnet101(), 1.0).unwrap();
    let stream = vec![
        // Impossible: the model's predicted latency alone blows this.
        Request { tenant: t, arrival_s: 0.0, deadline_s: Some(1e-6) },
        Request { tenant: t, arrival_s: 0.1, deadline_s: Some(1e9) },
        Request { tenant: t, arrival_s: 0.2, deadline_s: None },
    ];
    let rep = server.serve(&stream).unwrap();
    assert_eq!(rep.rejected, 1);
    assert_eq!(rep.served, 2);
}

#[test]
fn concurrent_clients_never_exceed_the_budget() {
    // N client threads submit against 3 registered models; their
    // submissions are stamped with wall arrival times and replayed on
    // the reactor, whose resident windows overlap in virtual time — the
    // shared MemSim ledger must never record more than the budget.
    let mut cfg = MultiTenantConfig::new(300 * MB);
    cfg.queue_cap = 64;
    cfg.global_cap = 256;
    let mut server = MultiTenantServer::new(Engine::builder().build(), cfg);
    let ids = [
        server.register(families::resnet101(), 1.0).unwrap(),
        server.register(families::yolov3(), 1.0).unwrap(),
        server.register(families::fcn(), 1.0).unwrap(),
    ];
    assert!(server.fleet_bytes() >= 2 * 300 * MB);

    let n_clients = 4;
    let per_client = 12;
    let mut joins = Vec::new();
    for ci in 0..n_clients {
        let client = server.client();
        joins.push(std::thread::spawn(move || {
            for k in 0..per_client {
                assert!(client.submit(ids[(ci + k) % ids.len()]));
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        }));
    }
    let rep = server.serve_concurrent(n_clients * per_client).unwrap();
    for j in joins {
        j.join().unwrap();
    }
    assert_eq!(rep.resolved(), n_clients * per_client);
    assert_eq!(rep.served, n_clients * per_client, "caps sized to admit everything");
    assert!(rep.within_budget(), "peak {} vs {}", rep.peak_bytes, rep.total_budget);
    assert!(rep.peak_bytes > 0);
    assert!(rep.batches >= 3, "each tenant ran at least one batch");
    for tr in &rep.traces {
        assert!(tr.e2e_s > 0.0 && tr.compute_s > 0.0);
    }
}

#[test]
fn trace_components_amortize_swap_across_the_batch() {
    // Force heavy batching on one tenant; the amortized per-request swap
    // share in a batch of k must be ~1/k of a solo request's.
    let mut cfg = MultiTenantConfig::new(120 * MB);
    cfg.max_batch = 8;
    cfg.queue_cap = 32;
    cfg.global_cap = 64;
    let mut server = MultiTenantServer::new(Engine::builder().build(), cfg);
    let t = server.register(families::resnet101(), 1.0).unwrap();
    // First request dispatches solo; the burst behind it batches.
    let mut stream = vec![Request { tenant: t, arrival_s: 0.0, deadline_s: None }];
    for i in 0..8 {
        stream.push(Request { tenant: t, arrival_s: 0.01 + 1e-4 * i as f64, deadline_s: None });
    }
    let rep = server.serve(&stream).unwrap();
    assert_eq!(rep.served, 9);
    let solo = rep.traces.iter().find(|tr| tr.batch == 1).expect("first request solo");
    let batched = rep.traces.iter().find(|tr| tr.batch == 8).expect("burst batch of 8");
    assert!(
        batched.swap_s < solo.swap_s / 4.0,
        "amortized swap {} vs solo {}",
        batched.swap_s,
        solo.swap_s
    );
    assert!(rep.within_budget());
}

#[test]
fn plan_cache_bytes_bounded_under_register_evict_thrash() {
    // Satellite of the planner PR, mirroring PR 3's `evict_lru` thrash
    // test: a register/evict storm drives repeated Eq. 1 re-partitions
    // through the shared plan cache, whose resident bytes must stay
    // under the configured `plan_cache_bytes` bound at every step (LRU
    // eviction, not unbounded growth), while recurring fleet
    // compositions still find warm entries.
    let cap = 4_000u64;
    let engine = Engine::builder().plan_cache_bytes(cap).build();
    let mut cfg = MultiTenantConfig::new(300 * MB);
    cfg.queue_cap = 8;
    cfg.global_cap = 24;
    let mut server = MultiTenantServer::new(engine, cfg);
    let mut live = std::collections::VecDeque::new();
    for round in 0..30 {
        let m = match round % 3 {
            0 => families::resnet101(),
            1 => families::yolov3(),
            _ => families::fcn(),
        };
        live.push_back(server.register(m, 1.0).unwrap());
        if live.len() > 2 {
            let victim = live.pop_front().unwrap();
            server.evict(victim).unwrap();
        }
        let st = server.engine().plan_stats();
        assert!(st.bytes <= cap, "round {round}: cache {} B > bound {cap} B", st.bytes);
        assert!(st.entries == 0 || st.bytes > 0);
    }
    let st = server.engine().plan_stats();
    assert!(st.bytes <= cap);
    // LRU eviction mechanics are unit-tested in planner::cache; here the
    // integration claims are the hard byte bound above and that the
    // bounded cache still pays off across recurring fleet compositions.
    assert!(
        st.hits + st.table_hits > 0,
        "recurring fleet compositions must find warm entries: {st:?}"
    );
    // The serving path still works on the thrashed cache.
    let t = *live.back().unwrap();
    let stream = vec![
        Request { tenant: t, arrival_s: 0.0, deadline_s: None },
        Request { tenant: t, arrival_s: 0.1, deadline_s: None },
    ];
    let rep = server.serve(&stream).unwrap();
    assert_eq!(rep.served, 2);
    assert!(rep.within_budget());
    let plan = rep.plan.expect("serve stamps planner stats");
    assert!(plan.bytes <= cap);
}

#[test]
fn plan_cache_is_not_blind_to_pinned_kv_load() {
    // Regression: two tenants with IDENTICAL chains but different pinned
    // KV loads must not share a cached schedule — the KV-heavy tenant
    // plans against a smaller swap window, so a shared entry would hand
    // it a partition whose peak overflows its real headroom.
    use swapnet::engine::PlanContext;
    let engine = Engine::builder().build();
    let model = families::llama7b();
    let budget = 2048 * MB;
    let light = engine
        .plan_decode(&model, budget, PlanContext { pinned_bytes: 0, batch: 1 })
        .unwrap();
    let heavy_kv = 900 * MB;
    let heavy = engine
        .plan_decode(&model, budget, PlanContext { pinned_bytes: heavy_kv, batch: 1 })
        .unwrap();
    assert!(
        heavy.budget_bytes < light.budget_bytes,
        "heavy tenant must see the KV-reduced window: {} vs {}",
        heavy.budget_bytes,
        light.budget_bytes
    );
    assert!(
        heavy.peak_bytes + heavy_kv <= budget,
        "heavy tenant's schedule must fit beside its KV: peak {} + kv {heavy_kv} > {budget}",
        heavy.peak_bytes
    );
    // Both entries live side by side: re-probing either context is a
    // cache hit, not a recompute, and returns that context's own plan.
    let st0 = engine.plan_stats();
    let light2 = engine
        .plan_decode(&model, budget, PlanContext { pinned_bytes: 0, batch: 1 })
        .unwrap();
    let heavy2 = engine
        .plan_decode(&model, budget, PlanContext { pinned_bytes: heavy_kv, batch: 1 })
        .unwrap();
    let st = engine.plan_stats();
    assert_eq!(st.hits, st0.hits + 2, "re-probes must hit their own entries");
    assert_eq!(light2.points, light.points);
    assert_eq!(heavy2.points, heavy.points);
}

#[test]
fn plan_cache_is_not_blind_to_decompress_drift() {
    // Regression (mirror of the pinned-KV blindness test above, for the
    // codec axis): a measured planner that chose Compressed because the
    // decompressor looked cheap must not keep serving that plan from the
    // cache after the decompress coefficient drifts past the fingerprint
    // quantization band. Sub-band noise, on the other hand, must not
    // shed warm entries.
    use swapnet::pipeline::{CodecMode, PipelineSpec, SwapVariant, VariantPolicy};
    use swapnet::planner::Planner;
    let prof = DeviceProfile::jetson_nx();
    let spec = PipelineSpec::default();
    let policy = VariantPolicy { codec: CodecMode::Auto, tile_max: 1 };
    let mut planner = Planner::measured(&prof, 7).with_policy(policy);
    let model = families::vgg19();
    let budget = 256 * MB;

    let sched0 = planner.plan(&model, budget, &spec).unwrap();
    assert!(
        sched0.variants.iter().any(|v| matches!(v, SwapVariant::Compressed)),
        "on the NX the fitted codec is cheaper than the PCIe bytes it saves, \
         so auto must pick Compressed: {:?}",
        sched0.variants
    );
    let st0 = planner.stats();
    let _ = planner.plan(&model, budget, &spec).unwrap();
    let st1 = planner.stats();
    assert_eq!(st1.hits, st0.hits + 1, "warm re-probe must hit");

    // Sub-band drift: a 0.2%-slow decompress observation stays inside the
    // quantization bucket — the fingerprint holds and the cache survives.
    let bytes = 100 * MB;
    let pred = planner.delay_model().decompress_s_per_byte * bytes as f64;
    planner.observe_decompress(bytes, pred * 1.002);
    let st2 = planner.stats();
    assert_eq!(st2.invalidations, st1.invalidations, "sub-band drift must not invalidate");
    let _ = planner.plan(&model, budget, &spec).unwrap();
    assert_eq!(planner.stats().hits, st1.hits + 1, "cache must stay warm under sub-band noise");

    // Band-crossing drift: a consistently 3x-slow decompressor. The EMA
    // pulls the codec scale past the 1/64 quantum within a few folds, the
    // fingerprint moves, and every cached plan keyed by the stale price
    // is dropped.
    for _ in 0..8 {
        planner.observe_decompress(bytes, pred * 3.0);
    }
    let st3 = planner.stats();
    assert!(
        st3.invalidations > st2.invalidations,
        "band-crossing decompress drift must invalidate cached variant choices: {st3:?}"
    );
    let sched1 = planner.plan(&model, budget, &spec).unwrap();
    let st4 = planner.stats();
    assert_eq!(st4.misses, st3.misses + 1, "post-drift probe must re-plan, not replay");
    assert!(
        !sched1.variants.iter().any(|v| matches!(v, SwapVariant::Compressed)),
        "a ~3x decompressor erases the NX codec win, so the re-plan must \
         fall back to plain swap-ins: {:?}",
        sched1.variants
    );
}
