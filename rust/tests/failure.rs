//! Failure injection: corrupted artifacts, truncated parameter files,
//! impossible budgets, broken skeletons — every failure must surface as
//! a clean error, never a panic or silent wrong answer.

// A failed unwrap IS the failure signal at this grain; the workspace
// unwrap ban (clippy::unwrap_used) is aimed at production code paths.
#![allow(clippy::unwrap_used)]

use std::path::PathBuf;

use swapnet::assembly::{synthetic_skeleton, AssemblyController, AssemblyMode};
use swapnet::config::{DeviceProfile, MB};
use swapnet::coordinator::{run_snet_model, SnetConfig};
use swapnet::delay::DelayModel;
use swapnet::memsim::MemSim;
use swapnet::model::artifacts::{artifacts_dir, ArtifactModel};
use swapnet::model::{families, BlockInfo};
use swapnet::scheduler;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("swapnet-fail-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn corrupted_meta_json_is_an_error() {
    let d = tmpdir("meta");
    std::fs::write(d.join("meta.json"), b"{\"name\": \"x\", ").unwrap();
    let err = ArtifactModel::load(&d).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("parsing") || msg.contains("json"), "{msg}");
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn meta_missing_required_fields_is_an_error() {
    let d = tmpdir("fields");
    std::fs::write(d.join("meta.json"), b"{\"name\": \"x\"}").unwrap();
    assert!(ArtifactModel::load(&d).is_err());
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn truncated_params_file_fails_loudly_not_wrongly() {
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    // Copy tiny_cnn, truncate one params file, and expect the literal
    // construction to reject the short buffer.
    let src = artifacts_dir().join("tiny_cnn");
    let d = tmpdir("trunc");
    for entry in std::fs::read_dir(&src).unwrap() {
        let e = entry.unwrap();
        std::fs::copy(e.path(), d.join(e.file_name())).unwrap();
    }
    let full = std::fs::read(d.join("params_000.bin")).unwrap();
    std::fs::write(d.join("params_000.bin"), &full[..full.len() / 2]).unwrap();

    let model = ArtifactModel::load(&d).unwrap();
    let rt = swapnet::runtime::Runtime::cpu().unwrap();
    let runner = swapnet::runtime::DirectRunner::new(&rt, model.clone(), 1);
    let n: usize = model.in_shape.iter().skip(1).product();
    let res = runner.forward(&vec![0.0f32; n]);
    assert!(res.is_err(), "truncated params must not silently execute");
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn wrong_input_length_rejected() {
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let model = ArtifactModel::load(&artifacts_dir().join("tiny_cnn")).unwrap();
    let rt = swapnet::runtime::Runtime::cpu().unwrap();
    let runner = swapnet::runtime::DirectRunner::new(&rt, model, 1);
    assert!(runner.forward(&[0.0f32; 7]).is_err());
}

#[test]
fn impossible_budget_is_a_clean_error_everywhere() {
    let prof = DeviceProfile::jetson_nx();
    let dm = DelayModel::from_profile(&prof);
    let m = families::vgg19(); // 478 MB atomic fc pair
    assert!(scheduler::schedule_model(&m, 20 * MB, &dm, &prof).is_err());
    assert!(run_snet_model(&m, 20 * MB, &prof, &SnetConfig::default()).is_err());
}

#[test]
fn zero_and_tiny_budgets_do_not_panic() {
    let prof = DeviceProfile::jetson_nx();
    let dm = DelayModel::from_profile(&prof);
    for budget in [0u64, 1, 1024] {
        let _ = scheduler::schedule_model(&families::resnet101(), budget, &dm, &prof);
    }
}

#[test]
fn skeleton_gap_and_overrun_rejected() {
    let prof = DeviceProfile::jetson_nx();
    let mut mem = MemSim::new(u64::MAX);
    let ctl = AssemblyController::new(AssemblyMode::ByReference, "t");
    let b = BlockInfo {
        index: 0,
        layer_lo: 0,
        layer_hi: 1,
        size_bytes: 4096,
        depth: 4,
        flops: 0,
    };
    // gap
    let mut sk = synthetic_skeleton(&b);
    sk[1].offset_bytes += 8;
    assert!(ctl.assemble(&b, &sk, 4096, &mut mem, &prof).is_err());
    // wrong total
    let sk2 = synthetic_skeleton(&b);
    assert!(ctl.assemble(&b, &sk2, 4000, &mut mem, &prof).is_err());
    assert_eq!(mem.current(), 0, "failed assembly must not leak");
}

#[test]
fn unknown_method_and_scenario_are_errors() {
    let prof = DeviceProfile::jetson_nx();
    let sc = swapnet::workload::uav();
    assert!(swapnet::coordinator::run_scenario(&sc, "Magic", &prof, &SnetConfig::default())
        .is_err());
    assert!(swapnet::workload::by_name("nonexistent").is_none());
}

#[test]
fn hlo_parse_failure_is_an_error() {
    let d = tmpdir("hlo");
    let bad = d.join("bad.hlo.txt");
    std::fs::write(&bad, "this is not HLO").unwrap();
    let rt = swapnet::runtime::Runtime::cpu().unwrap();
    assert!(rt.load_hlo(&bad).is_err());
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn oom_pressure_is_recorded_not_fatal() {
    // Run the DInf baseline on a device that cannot possibly hold it and
    // verify the simulator records OOM events instead of crashing — the
    // paper handles this by terminating non-DNN tasks.
    let mut prof = DeviceProfile::jetson_nx();
    prof.mem_total = 100 * MB;
    let mut mem = MemSim::new(prof.mem_total);
    let mut st = swapnet::storage::Storage::new(64 * MB);
    let r = swapnet::baselines::dinf(&families::vgg19(), &prof, &mut st, &mut mem);
    assert!(mem.oom_events > 0);
    assert!(r.peak_bytes > prof.mem_total);
}
