//! Engine facade integration tests: the unified API must be a faithful
//! veneer — SimBackend reproduces the coordinator's numbers exactly, the
//! PJRT backend matches DirectRunner bit-for-bit (artifact-gated), and
//! the server/metrics layers work identically over both backends.

// A failed unwrap IS the failure signal at this grain; the workspace
// unwrap ban (clippy::unwrap_used) is aimed at production code paths.
#![allow(clippy::unwrap_used)]

use swapnet::config::{DeviceProfile, MB};
use swapnet::coordinator::{run_scenario, run_snet_model, sample_snet_latencies, SnetConfig};
use swapnet::delay::DelayModel;
use swapnet::engine::Engine;
use swapnet::model::artifacts::{artifacts_dir, ArtifactModel};
use swapnet::model::families;
use swapnet::runtime::{DirectRunner, Runtime};
use swapnet::scheduler;
use swapnet::server::{serve, ServeConfig};
use swapnet::workload;

fn prof() -> DeviceProfile {
    DeviceProfile::jetson_nx()
}

#[test]
fn sim_backend_reproduces_coordinator_exactly() {
    // Same seed, same budget -> the facade must be bit-identical to the
    // historical run_snet_model path (it IS the same code underneath).
    let m = families::resnet101();
    let budget = 120 * MB;
    let cfg = SnetConfig { jitter: 0.02, seed: 9, ..Default::default() };
    let direct = run_snet_model(&m, budget, &prof(), &cfg).unwrap();

    let engine = Engine::builder().device(prof()).config(cfg).build();
    let handle = engine.register_with_budget(m, budget).unwrap();
    let rep = handle.infer_sim().unwrap();

    assert_eq!(rep.latency_s, direct.latency_s, "latency must match bit-for-bit");
    assert_eq!(rep.peak_bytes, direct.peak_bytes);
    assert_eq!(rep.n_blocks, direct.block_times.len());
    assert_eq!(rep.cache_hits, direct.cache_hits);
    assert_eq!(rep.cache_misses, direct.cache_misses);
}

#[test]
fn seeded_sampling_matches_fig14_path() {
    let m = families::resnet101();
    let budget = 120 * MB;
    let rec = sample_snet_latencies(&m, budget, &prof(), 6, 0.05, 7).unwrap();

    let cfg = SnetConfig { jitter: 0.05, seed: 7, ..Default::default() };
    let engine = Engine::builder().device(prof()).config(cfg).build();
    let handle = engine.register_with_budget(m, budget).unwrap();
    for (r, &want) in rec.samples().iter().enumerate() {
        let got = handle.infer_sim_seeded(r as u64).unwrap().latency_s;
        assert_eq!(got, want, "run {r}");
    }
}

#[test]
fn engine_scenario_matches_coordinator_facade() {
    let sc = workload::uav();
    let p = prof();
    let cfg = SnetConfig::default();
    let engine = Engine::builder().device(p.clone()).config(cfg).build();
    for method in ["DInf", "TPrg", "DCha", "SNet"] {
        let via_engine = engine.run_scenario(&sc, method).unwrap();
        let via_coord = run_scenario(&sc, method, &p, &cfg).unwrap();
        assert_eq!(via_engine.len(), via_coord.len());
        for (a, b) in via_engine.iter().zip(&via_coord) {
            assert_eq!(a.model, b.model);
            assert_eq!(a.method, b.method);
            assert_eq!(a.peak_bytes, b.peak_bytes, "{method}/{}", a.model);
            assert_eq!(a.latency_s, b.latency_s, "{method}/{}", a.model);
            assert_eq!(a.accuracy, b.accuracy);
        }
    }
}

#[test]
fn registration_schedule_matches_scheduler() {
    // The handle's schedule is the paper's offline partition decision.
    let m = families::resnet101();
    let budget = 102 * MB;
    let engine = Engine::builder().device(prof()).build();
    let handle = engine.register_with_budget(m.clone(), budget).unwrap();
    let dm = DelayModel::from_profile(&prof());
    let want = scheduler::schedule_model(&m, budget, &dm, &prof()).unwrap();
    let got = handle.schedule();
    assert_eq!(got.n_blocks, want.n_blocks);
    assert_eq!(got.points, want.points);
    assert_eq!(got.peak_bytes, want.peak_bytes);
}

#[test]
fn infeasible_registration_is_a_clean_error() {
    let engine = Engine::builder().device(prof()).build();
    let err = engine
        .register_with_budget(families::vgg19(), 50 * MB)
        .err()
        .expect("50 MB cannot fit VGG-19's fc pair");
    let msg = format!("{err:#}");
    assert!(msg.contains("vgg"), "{msg}");
}

#[test]
fn unified_server_runs_on_the_sim_backend() {
    // The same batcher/metrics loop that serves PJRT also serves the
    // cost-model backend on a virtual clock.
    let engine = Engine::builder().device(prof()).memory_budget(120 * MB).build();
    let handle = engine.register(families::resnet101()).unwrap();
    let rep = serve(&handle, &ServeConfig { requests: 10, rate_hz: 50.0, ..Default::default() })
        .unwrap();
    assert_eq!(rep.served, 10);
    assert_eq!(rep.latency.len(), 10);
    assert!(rep.latency.p(50.0) > 0.3, "simulated ResNet service time");
    assert!(rep.throughput_rps > 0.0);
}

#[test]
fn ablation_switches_flow_through_the_builder() {
    let m = families::yolov3();
    let budget = 180 * MB;
    let full = Engine::builder()
        .device(prof())
        .build()
        .register_with_budget(m.clone(), budget)
        .and_then(|h| h.infer_sim())
        .unwrap();
    let no_uni = Engine::builder()
        .device(prof())
        .config(SnetConfig { unified_addressing: false, ..Default::default() })
        .build()
        .register_with_budget(m, budget)
        .and_then(|h| h.infer_sim())
        .unwrap();
    assert!(no_uni.latency_s > full.latency_s);
    assert!(no_uni.peak_bytes > full.peak_bytes);
    assert!(no_uni.cache_misses > 0, "standard path reads through the page cache");
    assert_eq!(full.cache_misses, 0, "zero-copy path bypasses the page cache");
}

/// PJRT side of the facade, gated on real artifacts + a real XLA backend
/// (the vendored stub reports compile errors, which also skips).
#[test]
fn pjrt_backend_matches_direct_runner_bit_for_bit() {
    let dir = artifacts_dir().join("tiny_cnn");
    if !dir.join("meta.json").exists() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let model = ArtifactModel::load(&dir).unwrap();
    let engine = match Engine::builder().build_pjrt() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping: {e:#}");
            return;
        }
    };
    let handle = match engine.register_artifact(model.clone()) {
        Ok(h) => h,
        Err(e) => {
            eprintln!("skipping: {e:#}");
            return;
        }
    };

    let rt = Runtime::cpu().unwrap();
    let n: usize = model.in_shape.iter().skip(1).product();
    let x: Vec<f32> = (0..n).map(|i| (i % 97) as f32 / 97.0).collect();
    let want = DirectRunner::new(&rt, model, 1).forward(&x).unwrap();

    // Partitioned execution reads params through the same literal path as
    // DirectRunner, so outputs must agree bit-for-bit.
    let rep = handle.infer_batch(&x, 1, Some(&[2, 4])).unwrap();
    let got = rep.output.expect("real backend returns output");
    assert_eq!(got, want, "Engine+PjrtBackend must match DirectRunner bit-for-bit");
    assert_eq!(rep.n_blocks, 3);
    assert_eq!(rep.backend, "pjrt");
    assert!(rep.latency_s > 0.0);
}
