//! Host data path integration tests: pooled reads land byte-identical
//! payloads, pool reuse invariants hold under the pipeline's residency
//! bound (slot count, zero steady-state allocations), checkout/return
//! survives concurrent stress, and — when the reference artifact exists
//! — the pooled swapped execution produces byte-identical model outputs
//! to the direct (unpooled) oracle in both Sequential and Overlapped
//! modes.

// A failed unwrap IS the failure signal at this grain; the workspace
// unwrap ban (clippy::unwrap_used) is aimed at production code paths.
#![allow(clippy::unwrap_used)]

use std::collections::VecDeque;
use std::path::PathBuf;

use swapnet::hostmem::{aligned_len, BlockBuffer, BufferPool, ALIGN};
use swapnet::pipeline::PipelineSpec;
use swapnet::storage::{read_file_into, read_into_slice};

/// Write `n` deterministic synthetic "unit parameter" files.
fn synthetic_files(tag: &str, sizes: &[usize]) -> (PathBuf, Vec<PathBuf>) {
    let dir = std::env::temp_dir().join(format!("swapnet-hostmem-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut paths = Vec::new();
    for (i, &sz) in sizes.iter().enumerate() {
        let path = dir.join(format!("unit{i}.bin"));
        let data: Vec<u8> = (0..sz).map(|b| ((b * 31 + i * 7) % 251) as u8).collect();
        std::fs::write(&path, &data).unwrap();
        paths.push(path);
    }
    (dir, paths)
}

#[test]
fn pooled_reads_are_byte_identical_to_buffered_reads() {
    let sizes = [10_000usize, ALIGN, 1, 3 * ALIGN + 17];
    let (dir, paths) = synthetic_files("ident", &sizes);
    let pool = BufferPool::new(*sizes.iter().max().unwrap(), 1);
    for p in &paths {
        let mut slot = pool.checkout();
        let o = read_file_into(p, true, &mut slot).unwrap();
        let expect = std::fs::read(p).unwrap();
        assert_eq!(o.bytes, expect.len());
        assert_eq!(slot.as_slice(), &expect[..], "{}", p.display());
    }
    assert_eq!(pool.stats().bytes_copied, 0, "pooled reads land in place");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn slot_count_respects_residency_times_channels() {
    // Emulate the pipeline's residency-m window over 8 blocks x many
    // rounds: at most m slots live at once, so the pool must never grow
    // beyond the m x channels pre-size.
    let sizes = vec![20_000usize; 8];
    let (dir, paths) = synthetic_files("window", &sizes);
    for (m, channels) in [(1usize, 1usize), (2, 1), (3, 2)] {
        let spec = PipelineSpec { residency_m: m, swap_channels: channels };
        let pool = BufferPool::for_pipeline(20_000, &spec);
        for _round in 0..6 {
            let mut live = VecDeque::new();
            for p in &paths {
                if live.len() == m {
                    live.pop_front(); // block i-m swapped out
                }
                let mut slot = pool.checkout();
                read_file_into(p, true, &mut slot).unwrap();
                live.push_back(slot);
            }
        }
        let s = pool.stats();
        assert!(
            s.slots <= (m * channels) as u64,
            "m={m} c={channels}: {} slots exceed the pipeline bound",
            s.slots
        );
        assert!(s.peak_checked_out <= (m * channels) as u64);
        assert_eq!(s.checked_out, 0, "every slot returned");
        assert_eq!(s.checkouts, 48);
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn steady_state_swap_loop_allocates_nothing() {
    let sizes = vec![30_000usize, 50_000, 12_345, 70_000];
    let (dir, paths) = synthetic_files("steady", &sizes);
    let pool = BufferPool::for_pipeline(*sizes.iter().max().unwrap(), &PipelineSpec::default());
    // Warmup round: the pool creates its slots.
    for p in &paths {
        let mut slot = pool.checkout();
        read_file_into(p, true, &mut slot).unwrap();
    }
    let warm = pool.stats();
    assert!(warm.alloc_events >= 1);
    // Steady state: 50 more rounds, zero further allocations.
    for _ in 0..50 {
        for p in &paths {
            let mut slot = pool.checkout();
            let o = read_file_into(p, true, &mut slot).unwrap();
            assert!(!o.grew, "steady-state read must not grow its slot");
        }
    }
    let s = pool.stats();
    assert_eq!(
        s.alloc_events, warm.alloc_events,
        "steady-state swap loop performed heap allocations"
    );
    assert_eq!(s.bytes_copied, 0);
    assert_eq!(s.reuses, s.checkouts - s.slots);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn concurrent_checkout_return_stress() {
    // Overlapped-mode shape: loader and executor threads checking slots
    // out and returning them concurrently. The pool must stay
    // consistent: everything returned, peak bounded by the thread
    // count, payloads uncorrupted.
    let threads = 4usize;
    let iters = 200usize;
    let pool = BufferPool::new(4096, threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let pool = pool.clone();
            s.spawn(move || {
                for i in 0..iters {
                    let mut slot = pool.checkout();
                    let fill = ((t * 131 + i) % 251) as u8;
                    let n = 1 + (i % 4096);
                    slot.spare_mut()[..n].fill(fill);
                    slot.set_len(n);
                    assert!(slot.as_slice().iter().all(|&b| b == fill));
                    // slot drops -> returns to the pool
                }
            });
        }
    });
    let s = pool.stats();
    assert_eq!(s.checked_out, 0);
    assert_eq!(s.checkouts, (threads * iters) as u64);
    assert!(s.peak_checked_out <= threads as u64);
    assert!(s.slots <= threads as u64);
    assert_eq!(s.alloc_events, s.slots, "allocations only at slot creation");
    assert!(s.reuses > 0);
}

#[test]
fn aligned_len_contract() {
    assert_eq!(aligned_len(0), 0);
    assert_eq!(aligned_len(1), ALIGN);
    assert_eq!(aligned_len(ALIGN), ALIGN);
    assert_eq!(aligned_len(ALIGN + 1), 2 * ALIGN);
}

#[test]
fn misaligned_region_reads_still_correct_via_fallback() {
    let (dir, paths) = synthetic_files("fallback", &[9_000]);
    let expect = std::fs::read(&paths[0]).unwrap();
    let mut buf = BlockBuffer::with_capacity(16_000);
    // A deliberately short destination window (payload-sized, not
    // page-rounded) denies O_DIRECT; the buffered fallback must land
    // identical bytes and report the degradation.
    let o = {
        let dst = &mut buf.spare_mut()[..9_000];
        read_into_slice(&paths[0], true, dst).unwrap()
    };
    assert!(o.fallback);
    assert_eq!(o.bytes, expect.len());
    buf.set_len(o.bytes);
    assert_eq!(buf.as_slice(), &expect[..]);
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// artifact-gated: byte-identical model outputs through the pooled path
// ---------------------------------------------------------------------

fn tiny() -> Option<swapnet::model::artifacts::ArtifactModel> {
    let dir = swapnet::model::artifacts::artifacts_dir().join("tiny_cnn");
    if dir.join("meta.json").exists() {
        Some(swapnet::model::artifacts::ArtifactModel::load(&dir).unwrap())
    } else {
        eprintln!("skipping: no artifacts");
        None
    }
}

#[test]
fn pooled_execution_matches_direct_oracle_bytes() {
    use swapnet::pipeline::real::{run_partitioned_spec, ExecStrategy};
    use swapnet::runtime::{DirectRunner, Runtime};
    let Some(model) = tiny() else { return };
    let rt = Runtime::cpu().unwrap();
    let n: usize = model.in_shape.iter().skip(1).product();
    let x: Vec<f32> = (0..n).map(|i| ((i * 13) % 97) as f32 / 97.0).collect();
    // The pre-pool oracle: plain fs::read per unit, no pooling.
    let oracle = DirectRunner::new(&rt, model.clone(), 1).forward(&x).unwrap();
    for strat in [ExecStrategy::Sequential, ExecStrategy::Overlapped] {
        let rep = run_partitioned_spec(
            &rt,
            &model,
            1,
            &[2, 4],
            strat,
            &x,
            &PipelineSpec::default(),
        )
        .unwrap();
        assert_eq!(rep.output.len(), oracle.len(), "{strat:?}");
        // Byte-identical: the pooled path must not perturb a single
        // f32 bit pattern relative to the unpooled oracle.
        for (a, b) in rep.output.iter().zip(&oracle) {
            assert_eq!(a.to_bits(), b.to_bits(), "{strat:?}: {a} vs {b}");
        }
        assert_eq!(rep.pool.bytes_copied, 0, "{strat:?}");
        assert!(rep.pool.reuses > 0, "{strat:?}: slots must recycle");
    }
}

#[test]
fn pooled_overlapped_pool_invariants_on_real_model() {
    use swapnet::pipeline::real::{run_partitioned_pooled, ExecStrategy};
    use swapnet::runtime::Runtime;
    let Some(model) = tiny() else { return };
    let rt = Runtime::cpu().unwrap();
    let n: usize = model.in_shape.iter().skip(1).product();
    let x: Vec<f32> = (0..n).map(|i| (i % 89) as f32 / 89.0).collect();
    for m in [1usize, 2, 3] {
        let spec = PipelineSpec::with_residency(m);
        let slot = swapnet::pipeline::real::pool_slot_bytes(&model, &[1, 2, 3, 4]).unwrap();
        let pool = BufferPool::for_pipeline(slot, &spec);
        // Several requests against ONE pool: warm after the first.
        let mut baseline = None;
        for req in 0..3 {
            let rep = run_partitioned_pooled(
                &rt,
                &model,
                1,
                &[1, 2, 3, 4],
                ExecStrategy::Overlapped,
                &x,
                &spec,
                &pool,
            )
            .unwrap();
            let s = rep.pool;
            assert!(
                s.slots <= pool.slot_limit(),
                "m={m}: {} slots exceed {}",
                s.slots,
                pool.slot_limit()
            );
            assert!(s.peak_checked_out <= m as u64);
            match baseline {
                None => baseline = Some(s.alloc_events),
                Some(warm) => assert_eq!(
                    s.alloc_events, warm,
                    "m={m} request {req}: steady state allocated"
                ),
            }
        }
    }
}
