//! Integration tests for the static schedule verifier (DESIGN.md §11):
//! the frozen bug corpus is rejected with exact minimal traces, its
//! corrected twins prove, every feasible family plan proves, and the
//! engine's admission gate exposes the same check.

// A failed unwrap IS the failure signal at this grain; the workspace
// unwrap ban (clippy::unwrap_used) is aimed at production code paths.
#![allow(clippy::unwrap_used)]

use swapnet::config::MB;
use swapnet::engine::{Engine, PlanContext, SnetConfig};
use swapnet::model::families;
use swapnet::pipeline::PipelineSpec;
use swapnet::planner::{cache::DEFAULT_PINNED_BAND_BYTES, PlanCacheConfig, Planner};
use swapnet::verify::{self, checker, corpus, Bounds, Outcome, Verdict};

#[test]
fn corpus_cases_reject_with_exact_minimal_traces() {
    let cases = corpus::cases();
    assert!(cases.len() >= 4, "corpus lost cases: {}", cases.len());
    for case in &cases {
        match checker::check(&case.program, &case.discipline, &Bounds::default()) {
            Verdict::Rejected(cx) => {
                assert_eq!(
                    cx.violation.kind(),
                    case.expected_kind,
                    "{}: wrong violation: {}",
                    case.name,
                    cx.violation
                );
                assert_eq!(
                    cx.trace.len(),
                    case.expected_trace_len,
                    "{}: trace no longer minimal:\n{}",
                    case.name,
                    cx.render()
                );
                assert!(!cx.trace.is_empty(), "{}: empty trace", case.name);
                // The render carries the full ledger timeline (CI artifact
                // format) — every event with its live/pinned columns.
                let r = cx.render();
                assert!(r.contains("minimal trace"), "{}: {r}", case.name);
                assert!(r.contains(case.expected_kind), "{}: {r}", case.name);
            }
            other => panic!("{}: expected rejection, got {other:?}", case.name),
        }
    }
}

#[test]
fn corpus_fixed_twins_prove() {
    for case in corpus::cases() {
        let (prog, disc) = case.fixed();
        match checker::check(&prog, &disc, &Bounds::default()) {
            Verdict::Proved(p) => {
                assert!(p.states > 0 && p.transitions > 0, "{}: empty proof", case.name);
            }
            other => panic!(
                "{}: the corrected twin must prove (the fix is sufficient), got {other:?}",
                case.name
            ),
        }
    }
}

#[test]
fn every_feasible_family_plan_proves() {
    let prof = swapnet::config::DeviceProfile::jetson_nx();
    let spec = PipelineSpec::default();
    let mut planner = Planner::analytic(&prof);
    for name in ["vgg19", "resnet101", "yolov3", "fcn", "llama7b"] {
        let model = families::by_name(name).unwrap();
        let mut proved = 0;
        for mb in [64u64, 128, 256, 1024, 2048] {
            let Ok(sched) = planner.plan(&model, mb * MB, &spec) else {
                continue; // refusal admits nothing — vacuously safe
            };
            match verify::verify_schedule(&model, &sched, &spec) {
                Ok(Outcome::Proved(p)) => {
                    proved += 1;
                    // The checker's exhaustive worst case must agree with
                    // the planner's claimed peak exactly — the claim is
                    // not an upper bound, it is the reachable maximum.
                    assert_eq!(
                        p.worst_live_bytes, sched.peak_bytes,
                        "{name} @ {mb} MB: claim {} vs reachable {}",
                        sched.peak_bytes, p.worst_live_bytes
                    );
                }
                other => panic!("{name} @ {mb} MB: {other:?}"),
            }
        }
        assert!(proved > 0, "{name}: no feasible budget in the sweep");
    }
}

#[test]
fn tiled_plans_prove_with_working_set_accounting() {
    use swapnet::pipeline::{CodecMode, SwapVariant, VariantPolicy};
    use swapnet::scheduler;
    let prof = swapnet::config::DeviceProfile::jetson_nx();
    let spec = PipelineSpec::default();
    let policy = VariantPolicy { codec: CodecMode::Off, tile_max: 4 };
    let model = families::vgg19();
    let plain_floor = scheduler::minimal_budget_spec(&model, &spec);
    let tiled_floor = scheduler::minimal_budget_policy(&model, &spec, policy);
    assert!(tiled_floor < plain_floor, "tiling must lower the feasible floor");
    let mut planner = Planner::analytic(&prof).with_policy(policy);
    let sched = planner
        .plan(&model, tiled_floor, &spec)
        .expect("the advertised policy floor must be accepted under the policy");
    assert!(
        sched.variants.iter().any(|v| matches!(v, SwapVariant::Tiled { .. })),
        "a sub-plain-floor budget requires at least one tiled block: {:?}",
        sched.variants
    );
    // The admission gate abstracts each tiled block to its tile working
    // set; the checker's exhaustive worst case must equal the claim.
    match verify::verify_schedule(&model, &sched, &spec).unwrap() {
        Outcome::Proved(p) => assert_eq!(
            p.worst_live_bytes, sched.peak_bytes,
            "claim vs reachable max under working-set accounting"
        ),
        Outcome::Unprovable { reason } => panic!("not provable: {reason}"),
    }
}

#[test]
fn llama7b_decode_plan_proves_at_2gb_with_pinned_kv() {
    let prof = swapnet::config::DeviceProfile::jetson_nx();
    let spec = PipelineSpec::default();
    let mut planner =
        Planner::for_source(Default::default(), &prof, 0, PlanCacheConfig::default());
    let model = families::llama7b();
    let ctx = PlanContext { pinned_bytes: 96 * MB, batch: 4 };
    let sched = planner
        .plan_decode(&model, 2048 * MB, &spec, ctx)
        .expect("llama7b must plan at the paper's 2 GB decode point");
    // Rebuild the full-ledger program: plan_decode returns a schedule
    // relative to the KV-reduced budget, so re-add the pinned band
    // ceiling on both sides and let growth events join mid-sweep.
    let ceiling = (ctx.pinned_bytes / DEFAULT_PINNED_BAND_BYTES + 1) * DEFAULT_PINNED_BAND_BYTES;
    let mut prog = verify::ProgramSpec::from_schedule(&model, &sched, &spec).unwrap();
    prog.budget_bytes = prog.budget_bytes.saturating_add(ceiling);
    prog.pinned_bytes = ceiling;
    prog.kv_growth = vec![16 * MB, 16 * MB, 32 * MB];
    match verify::run(&prog).expect("decode plan must not be rejected") {
        Outcome::Proved(p) => assert!(p.states > 0),
        Outcome::Unprovable { reason } => panic!("not provable: {reason}"),
    }
}

#[test]
fn engine_registration_is_verifier_gated_and_reexposes_the_proof() {
    let engine = Engine::builder().build();
    let h = engine
        .register_with_budget(families::resnet101(), 120 * MB)
        .expect("feasible registration passes the admission gate");
    let proof = engine.verify_plan(&h).expect("admitted plans re-verify");
    assert!(proof.states > 0 && proof.transitions >= proof.states.saturating_sub(1));
    assert!(proof.worst_live_blocks <= 2, "m=2 residency: {}", proof.worst_live_blocks);
}

#[test]
fn ablation_without_partition_scheduling_still_admits() {
    // w/o-pat-sch intentionally overshoots the budget; the admission
    // gate must drop only the budget invariant for it (residency,
    // exact-free, claimed-peak, deadlock-freedom still hold).
    let engine = Engine::builder()
        .config(SnetConfig { partition_scheduling: false, ..Default::default() })
        .build();
    let h = engine
        .register_with_budget(families::resnet101(), 120 * MB)
        .expect("naive equal-split plans must still admit");
    engine.verify_plan(&h).expect("the discipline invariants prove even unbudgeted");
}

#[test]
fn overcommitted_pinned_load_is_rejected_before_any_event() {
    let prog = verify::ProgramSpec {
        label: "pinned-over-budget".into(),
        blocks: vec![10],
        tile_full_bytes: Vec::new(),
        residency_m: 2,
        swap_channels: 1,
        budget_bytes: 100,
        claimed_peak_bytes: 10,
        pinned_bytes: 150,
        kv_growth: Vec::new(),
    };
    let err = verify::run(&prog).expect_err("base load alone exceeds the budget");
    match err {
        verify::VerifyError::Unsafe(cx) => {
            assert_eq!(cx.violation.kind(), "budget-exceeded");
            assert!(cx.trace.is_empty(), "violation precedes any event");
        }
        other => panic!("expected Unsafe, got {other:?}"),
    }
}
