//! Cross-module integration tests: scenarios x methods x devices, the
//! profiler-to-scheduler loop, adaptation consistency, and the real
//! artifact execution path (skipped gracefully when `make artifacts` has
//! not run).

// A failed unwrap IS the failure signal at this grain; the workspace
// unwrap ban (clippy::unwrap_used) is aimed at production code paths.
#![allow(clippy::unwrap_used)]

use swapnet::config::{DeviceProfile, MB};
use swapnet::coordinator::{run_scenario, run_snet_model, scenario_budgets, SnetConfig};
use swapnet::delay::{profiler, DelayModel};
use swapnet::model::{artifacts, families};
use swapnet::scheduler::{self, adapt::AdaptiveScheduler};
use swapnet::workload;

#[test]
fn every_scenario_method_device_combination_runs() {
    for dev in [DeviceProfile::jetson_nx(), DeviceProfile::jetson_nano()] {
        for sc_name in ["self-driving", "rsu", "uav"] {
            let sc = workload::by_name(sc_name).unwrap();
            for method in ["DInf", "TPrg", "DCha", "SNet"] {
                let rows = run_scenario(&sc, method, &dev, &SnetConfig::default())
                    .unwrap_or_else(|e| panic!("{sc_name}/{method}/{}: {e}", dev.name));
                assert_eq!(rows.len(), sc.models.len());
                for r in &rows {
                    assert!(r.peak_bytes > 0, "{sc_name}/{method} {r:?}");
                    assert!(r.latency_s > 0.0 && r.latency_s < 10.0, "{r:?}");
                    assert!(r.accuracy > 40.0 && r.accuracy <= 100.0, "{r:?}");
                }
            }
        }
    }
}

#[test]
fn snet_always_within_budget_across_scenarios() {
    let prof = DeviceProfile::jetson_nx();
    for sc_name in ["self-driving", "rsu", "uav"] {
        let sc = workload::by_name(sc_name).unwrap();
        let budgets = scenario_budgets(&sc, &prof);
        for (m, &b) in sc.models.iter().zip(&budgets) {
            let run = run_snet_model(m, b, &prof, &SnetConfig::default()).unwrap();
            assert!(
                run.peak_bytes <= b,
                "{sc_name}/{}: peak {} > budget {}",
                m.name,
                run.peak_bytes / MB,
                b / MB
            );
        }
    }
}

#[test]
fn snet_lossless_and_ordering_vs_baselines() {
    let prof = DeviceProfile::jetson_nx();
    let sc = workload::self_driving();
    let dinf = run_scenario(&sc, "DInf", &prof, &SnetConfig::default()).unwrap();
    let snet = run_scenario(&sc, "SNet", &prof, &SnetConfig::default()).unwrap();
    let tprg = run_scenario(&sc, "TPrg", &prof, &SnetConfig::default()).unwrap();
    for ((d, s), t) in dinf.iter().zip(&snet).zip(&tprg) {
        assert_eq!(d.accuracy, s.accuracy, "SNet lossless");
        assert!(t.accuracy < d.accuracy, "TPrg lossy");
        assert!(s.peak_bytes < d.peak_bytes, "SNet saves memory vs DInf");
        assert!(s.peak_bytes < t.peak_bytes, "SNet saves memory vs TPrg");
        assert!(d.latency_s <= s.latency_s, "DInf is the latency floor");
    }
}

#[test]
fn fitted_profile_drives_scheduler_to_same_decisions() {
    // Close the Fig 9 loop: coefficients recovered by regression must
    // lead the scheduler to (near-)identical partitions as ground truth.
    let prof = DeviceProfile::jetson_nx();
    let fit = profiler::fit(&profiler::measure_sweep(&prof, 400, 0.02, 9));
    let dm_true = DelayModel::from_profile(&prof);
    let dm_fit = profiler::fitted_delay_model(&prof, &fit);
    let m = families::resnet101();
    let s_true = scheduler::schedule_model(&m, 125 * MB, &dm_true, &prof).unwrap();
    let s_fit = scheduler::schedule_model(&m, 125 * MB, &dm_fit, &prof).unwrap();
    assert_eq!(s_true.n_blocks, s_fit.n_blocks);
    let lat_rel = (s_true.predicted_latency_s - s_fit.predicted_latency_s).abs()
        / s_true.predicted_latency_s;
    assert!(lat_rel < 0.1, "fitted model diverges: {lat_rel}");
}

#[test]
fn adaptation_agrees_with_fresh_scheduling() {
    let prof = DeviceProfile::jetson_nx();
    let m = families::resnet101();
    let dm = DelayModel::from_profile(&prof);
    let mut ad = AdaptiveScheduler::register(m.clone(), &prof, 6);
    for budget in [150 * MB, 125 * MB, 100 * MB] {
        let fast = ad.adapt(budget).unwrap();
        let fresh = scheduler::schedule_model(&m, budget, &dm, &prof).unwrap();
        assert_eq!(fast.n_blocks, fresh.n_blocks, "budget {}", budget / MB);
        assert_eq!(fast.points, fresh.points);
    }
}

#[test]
fn ablation_deltas_have_paper_direction_on_both_processors() {
    let prof = DeviceProfile::jetson_nx();
    for m in [families::resnet101(), families::yolov3()] {
        let budget = scheduler::minimal_budget(&m).max(m.size_bytes() * 2 / 3);
        let full = run_snet_model(&m, budget, &prof, &SnetConfig::default()).unwrap();
        let wo_uni = run_snet_model(
            &m,
            budget,
            &prof,
            &SnetConfig { unified_addressing: false, ..Default::default() },
        )
        .unwrap();
        // GPU models suffer the conversion+copy; CPU models at least the
        // page-cache copy.
        let mem_growth = wo_uni.peak_bytes as f64 / full.peak_bytes as f64;
        assert!(mem_growth > 1.3, "{}: only {mem_growth}", m.name);
    }
}

#[test]
fn jitter_produces_distribution_not_constant() {
    let prof = DeviceProfile::jetson_nx();
    let m = families::resnet101();
    let rec =
        swapnet::coordinator::sample_snet_latencies(&m, 125 * MB, &prof, 30, 0.05, 3).unwrap();
    let spread = rec.p(95.0) - rec.p(5.0);
    assert!(spread > 0.005, "jittered spread too small: {spread}");
    // deterministic reproduction with the same seed
    let rec2 =
        swapnet::coordinator::sample_snet_latencies(&m, 125 * MB, &prof, 30, 0.05, 3).unwrap();
    assert_eq!(rec.samples(), rec2.samples());
}

// ---------------------------------------------------------------------
// real artifact execution (requires `make artifacts`)
// ---------------------------------------------------------------------

fn artifacts_present() -> bool {
    artifacts::artifacts_dir().join("manifest.json").exists()
}

#[test]
fn all_artifact_models_execute_end_to_end() {
    if !artifacts_present() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let rt = swapnet::runtime::Runtime::cpu().unwrap();
    for model in artifacts::load_manifest(&artifacts::artifacts_dir()).unwrap() {
        let batch = model.batches.first().copied().unwrap_or(1);
        let runner = swapnet::runtime::DirectRunner::new(&rt, model.clone(), batch);
        let n: usize = model.in_shape.iter().skip(1).product();
        let out = runner
            .forward(&vec![0.25f32; n * batch])
            .unwrap_or_else(|e| panic!("{}: {e}", model.name));
        let expect: usize = model.out_shape.iter().skip(1).product::<usize>() * batch;
        assert_eq!(out.len(), expect, "{}", model.name);
        assert!(out.iter().all(|x| x.is_finite()), "{}", model.name);
    }
}

#[test]
fn pruned_models_are_really_smaller_with_measured_accuracy() {
    if !artifacts_present() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let dir = artifacts::artifacts_dir();
    let base = artifacts::ArtifactModel::load(&dir.join("tiny_cnn")).unwrap();
    let mut last = u64::MAX;
    for p in ["tiny_cnn_p25", "tiny_cnn_p50", "tiny_cnn_p75"] {
        let m = artifacts::ArtifactModel::load(&dir.join(p)).unwrap();
        assert!(m.size_bytes < base.size_bytes, "{p} not smaller");
        assert!(m.size_bytes < last, "{p} not monotone");
        last = m.size_bytes;
        assert!(m.accuracy.is_some(), "{p} must carry measured accuracy");
    }
    // the harshest pruning must show a REAL accuracy cliff
    let p75 = artifacts::ArtifactModel::load(&dir.join("tiny_cnn_p75")).unwrap();
    assert!(
        p75.accuracy.unwrap() < base.accuracy.unwrap() - 0.1,
        "75% pruning must visibly hurt"
    );
}

#[test]
fn swapped_execution_is_lossless_on_real_model() {
    if !artifacts_present() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    use swapnet::pipeline::real::{run_partitioned, ExecStrategy};
    let rt = swapnet::runtime::Runtime::cpu().unwrap();
    let model =
        artifacts::ArtifactModel::load(&artifacts::artifacts_dir().join("tiny_cnn")).unwrap();
    let n: usize = model.in_shape.iter().skip(1).product();
    let x: Vec<f32> = (0..n).map(|i| ((i * 31) % 101) as f32 / 101.0).collect();
    let whole = run_partitioned(&rt, &model, 1, &[], ExecStrategy::Sequential, &x).unwrap();
    for pts in [vec![1], vec![3], vec![2, 4], vec![1, 2, 3, 4, 5]] {
        for strat in [ExecStrategy::Sequential, ExecStrategy::Overlapped] {
            let rep = run_partitioned(&rt, &model, 1, &pts, strat, &x).unwrap();
            for (a, b) in rep.output.iter().zip(&whole.output) {
                assert!((a - b).abs() < 1e-4, "{pts:?}: {a} vs {b}");
            }
        }
    }
}
