//! CI bench gate: merge per-bench `--json` emissions into one
//! `BENCH_summary.json` and fail (exit 1) on any regression beyond the
//! tolerance band versus the committed `BENCH_baseline.json`.
//!
//! ```text
//! bench_gate --baseline BENCH_baseline.json --out BENCH_summary.json \
//!            [--tol 0.10] part1.json part2.json ...
//! ```
//!
//! The tolerance defaults to the baseline's own `tolerance` field (then
//! 0.10). The comparison logic lives in `swapnet::metrics::emit` (unit
//! tested); this binary is the thin CLI over it.

use std::path::PathBuf;
use std::process::ExitCode;

use swapnet::metrics::emit::{gate, merge};
use swapnet::util::json::Json;

struct Args {
    baseline: PathBuf,
    out: PathBuf,
    tol: Option<f64>,
    parts: Vec<PathBuf>,
}

fn parse_args() -> Result<Args, String> {
    let mut baseline = None;
    let mut out = None;
    let mut tol = None;
    let mut parts = Vec::new();
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--baseline" => baseline = Some(PathBuf::from(it.next().ok_or("--baseline needs a path")?)),
            "--out" => out = Some(PathBuf::from(it.next().ok_or("--out needs a path")?)),
            "--tol" => {
                let v = it.next().ok_or("--tol needs a value")?;
                tol = Some(v.parse::<f64>().map_err(|e| format!("--tol `{v}`: {e}"))?);
            }
            "--help" | "-h" => {
                return Err("usage: bench_gate --baseline B.json --out S.json [--tol 0.1] parts..."
                    .to_string())
            }
            flag if flag.starts_with("--") => return Err(format!("unknown flag {flag}")),
            path => parts.push(PathBuf::from(path)),
        }
    }
    Ok(Args {
        baseline: baseline.ok_or("--baseline is required")?,
        out: out.ok_or("--out is required")?,
        tol,
        parts,
    })
}

fn read_json(path: &PathBuf) -> Result<Json, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("read {}: {e}", path.display()))?;
    Json::parse(&text).map_err(|e| format!("parse {}: {e}", path.display()))
}

fn run() -> Result<bool, String> {
    let args = parse_args()?;
    if args.parts.is_empty() {
        return Err("no bench emission files given".to_string());
    }
    let baseline = read_json(&args.baseline)?;
    let parts: Vec<Json> = args.parts.iter().map(read_json).collect::<Result<_, _>>()?;
    let summary = merge(&parts);
    std::fs::write(&args.out, format!("{summary}\n"))
        .map_err(|e| format!("write {}: {e}", args.out.display()))?;
    let tol = args
        .tol
        .or_else(|| baseline.get("tolerance").and_then(Json::as_f64))
        .unwrap_or(0.10);

    let outcome = gate(&baseline, &summary, tol);
    println!(
        "bench gate: {} metrics checked against {} (tolerance {:.0}%)",
        outcome.checked,
        args.baseline.display(),
        tol * 100.0
    );
    for (bench, metric, base, new) in &outcome.rows {
        let delta = if *base > 0.0 { 100.0 * (new - base) / base } else { 0.0 };
        println!("  {bench}/{metric}: baseline {base:.6e} -> {new:.6e} ({delta:+.1}%)");
    }
    if outcome.checked == 0 {
        println!(
            "  baseline gates nothing yet — bootstrap run; promote {} to seed it",
            args.out.display()
        );
    }
    for f in &outcome.failures {
        eprintln!("REGRESSION: {f}");
    }
    Ok(outcome.passed())
}

fn main() -> ExitCode {
    match run() {
        Ok(true) => {
            println!("bench gate PASSED");
            ExitCode::SUCCESS
        }
        Ok(false) => {
            eprintln!("bench gate FAILED");
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("bench_gate: {e}");
            ExitCode::FAILURE
        }
    }
}
