//! `cargo run --bin xtask -- lint` — the resource-discipline lint pass.
//!
//! Three rules, all scoped to the steady-state swap path (DESIGN.md §11):
//!
//!   A  alloc-pairing   every non-test fn that acquires ledger memory
//!                      (`.alloc(`, `try_alloc_pinned(`,
//!                      `acquire_residency(`, `acquire_window(`) must
//!                      also release it (`free(`, `release_residency(`,
//!                      `swap_out(`, `disassemble(`, `release_window(`)
//!                      or hand the id out through its signature
//!                      (`AllocId` / `ResidentBlock` / `WindowLease` /
//!                      `WindowAcquire`).
//!   B  heap-alloc      no `Vec::with_capacity` / `vec!` / `.to_vec()` /
//!                      `Box::new` in steady-state swap-path modules
//!                      (hostmem, storage, swap, pipeline::real,
//!                      blockstore, codec) — the buffer pool is the only
//!                      steady-state allocator.
//!   C  wall-clock      no `thread::spawn` / `Instant::now` in
//!                      virtual-clock modules (server::reactor,
//!                      server::multi, llm, blockstore) — determinism
//!                      depends on it.
//!
//! Suppress a finding with a justification comment on any line of the
//! offending fn (rule A) or anywhere above the offending line (B, C):
//!
//!     // lint: allow(<rule>): <reason>
//!
//! The rule names are `alloc-pairing`, `heap-alloc`, `wall-clock`.
//! `syn` is outside the offline crate universe, so this is a line
//! scanner: comments and string literals are stripped before token
//! matching, and everything from the first `#[cfg(test)]` down is
//! skipped (tests are allowed to allocate and double-free on purpose).

use std::fs;
use std::path::Path;
use std::process::ExitCode;

const ACQUIRE_TOKENS: &[&str] =
    &[".alloc(", "try_alloc_pinned(", "acquire_residency(", "acquire_window("];
const RELEASE_TOKENS: &[&str] =
    &["free(", "release_residency(", "swap_out(", "disassemble(", "release_window("];
const ESCAPE_TYPES: &[&str] = &["AllocId", "ResidentBlock", "WindowLease", "WindowAcquire"];

/// Rule B scope: the modules a swap traverses on every steady-state
/// block movement. Pool buffers are recycled; any other heap allocation
/// here is per-swap garbage.
const HEAP_FREE_FILES: &[&str] = &[
    "rust/src/hostmem/mod.rs",
    "rust/src/storage/mod.rs",
    "rust/src/swap/mod.rs",
    "rust/src/pipeline/real.rs",
    "rust/src/blockstore/mod.rs",
    "rust/src/codec/mod.rs",
];
const HEAP_TOKENS: &[&str] = &["Vec::with_capacity", "vec!", ".to_vec()", "Box::new"];

/// Rule C scope: modules whose correctness proofs assume the virtual
/// clock is the only clock.
const CLOCK_FILES: &[&str] = &[
    "rust/src/server/reactor.rs",
    "rust/src/server/multi.rs",
    "rust/src/llm/mod.rs",
    "rust/src/blockstore/mod.rs",
];
const CLOCK_TOKENS: &[&str] = &["thread::spawn", "Instant::now"];

struct Finding {
    file: String,
    line: usize,
    rule: &'static str,
    message: String,
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => {}
        other => {
            eprintln!("usage: xtask lint  (got {other:?})");
            return ExitCode::FAILURE;
        }
    }
    let root = repo_root();
    let mut findings = Vec::new();
    let mut files = 0usize;

    for file in rust_sources(&root) {
        let rel = file
            .strip_prefix(&root)
            .unwrap_or(&file)
            .to_string_lossy()
            .replace('\\', "/");
        let Ok(raw) = fs::read_to_string(&file) else {
            continue;
        };
        files += 1;
        let lines = strip_to_non_test(&raw);
        check_alloc_pairing(&rel, &lines, &mut findings);
        if HEAP_FREE_FILES.contains(&rel.as_str()) {
            check_tokens(&rel, &lines, HEAP_TOKENS, "heap-alloc", &mut findings);
        }
        if CLOCK_FILES.contains(&rel.as_str()) {
            check_tokens(&rel, &lines, CLOCK_TOKENS, "wall-clock", &mut findings);
        }
    }

    if findings.is_empty() {
        println!("xtask lint: {files} files clean (alloc-pairing, heap-alloc, wall-clock)");
        ExitCode::SUCCESS
    } else {
        for f in &findings {
            println!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.message);
        }
        println!("xtask lint: {} finding(s) in {files} files", findings.len());
        ExitCode::FAILURE
    }
}

fn repo_root() -> std::path::PathBuf {
    // CARGO_MANIFEST_DIR when run through cargo; cwd otherwise.
    std::env::var_os("CARGO_MANIFEST_DIR")
        .map(Into::into)
        .unwrap_or_else(|| std::env::current_dir().expect("cwd"))
}

fn rust_sources(root: &Path) -> Vec<std::path::PathBuf> {
    let mut out = Vec::new();
    walk(&root.join("rust").join("src"), &mut out);
    out.sort();
    out
}

fn walk(dir: &Path, out: &mut Vec<std::path::PathBuf>) {
    let Ok(rd) = fs::read_dir(dir) else {
        return;
    };
    for e in rd.flatten() {
        let p = e.path();
        if p.is_dir() {
            walk(&p, out);
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
}

/// One scanned line: code with comments/strings blanked, plus the raw
/// text (suppression comments live in the raw text).
struct Line {
    code: String,
    raw: String,
    no: usize,
}

/// Strip the file to scannable lines: cut everything from the first
/// `#[cfg(test)]` (test modules sit at the bottom of every file in this
/// repo), blank out string literals and comments in the code view, and
/// drop block-comment interiors.
fn strip_to_non_test(src: &str) -> Vec<Line> {
    let mut out = Vec::new();
    let mut in_block_comment = false;
    for (i, raw) in src.lines().enumerate() {
        if raw.trim_start().starts_with("#[cfg(test)]") {
            break;
        }
        let code = blank_line(raw, &mut in_block_comment);
        out.push(Line { code, raw: raw.to_string(), no: i + 1 });
    }
    out
}

/// Blank string literals, char literals, and comments, preserving
/// length where convenient (positions are only used for reporting).
fn blank_line(raw: &str, in_block_comment: &mut bool) -> String {
    let bytes = raw.as_bytes();
    let mut out = String::with_capacity(raw.len());
    let mut i = 0;
    let mut in_str = false;
    while i < bytes.len() {
        let rest = &raw[i..];
        // Multi-byte chars (— or § in prose strings/comments) must advance
        // by their full width or the next `&raw[i..]` slice panics.
        let step = rest.chars().next().map_or(1, char::len_utf8);
        if *in_block_comment {
            if rest.starts_with("*/") {
                *in_block_comment = false;
                i += 2;
            } else {
                i += step;
            }
            continue;
        }
        if in_str {
            if rest.starts_with('\\') {
                i += 2;
            } else if rest.starts_with('"') {
                in_str = false;
                i += 1;
            } else {
                i += step;
            }
            out.push(' ');
            continue;
        }
        if rest.starts_with("//") {
            break; // line comment: rest of line is not code
        }
        if rest.starts_with("/*") {
            *in_block_comment = true;
            i += 2;
            continue;
        }
        if rest.starts_with('"') {
            in_str = true;
            i += 1;
            out.push(' ');
            continue;
        }
        // char literal like 'x' or '\n' (lifetimes never close with ').
        if rest.starts_with('\'') && rest.len() >= 3 {
            let close = if rest.as_bytes()[1] == b'\\' { 3 } else { 2 };
            if rest.as_bytes().get(close) == Some(&b'\'') {
                i += close + 1;
                out.push(' ');
                continue;
            }
        }
        out.push(raw[i..].chars().next().expect("in-bounds char"));
        i += raw[i..].chars().next().map(char::len_utf8).unwrap_or(1);
    }
    out
}

fn suppressed(raw: &str, rule: &str) -> bool {
    raw.contains(&format!("lint: allow({rule})"))
}

/// Rule A: per-fn alloc/free pairing over brace-balanced fn bodies.
fn check_alloc_pairing(file: &str, lines: &[Line], findings: &mut Vec<Finding>) {
    let mut i = 0;
    while i < lines.len() {
        let l = &lines[i];
        let Some(fn_col) = find_fn(&l.code) else {
            i += 1;
            continue;
        };
        // Collect the fn's signature (through the opening brace) and
        // body (through the matching close).
        let mut sig = String::new();
        let mut depth: i64 = 0;
        let mut body_lines: Vec<usize> = Vec::new();
        let mut j = i;
        let mut opened = false;
        while j < lines.len() {
            let code = if j == i { &lines[j].code[fn_col..] } else { &lines[j].code[..] };
            for c in code.chars() {
                if !opened {
                    sig.push(c);
                }
                match c {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            body_lines.push(j);
            if opened && depth <= 0 {
                break;
            }
            // A bodyless trait/extern fn: `fn foo(...) -> T;`
            if !opened && code.contains(';') {
                break;
            }
            j += 1;
        }
        if opened {
            let acquire_at = body_lines.iter().find_map(|&k| {
                ACQUIRE_TOKENS
                    .iter()
                    .any(|t| lines[k].code.contains(t))
                    .then_some(lines[k].no)
            });
            if let Some(no) = acquire_at {
                let releases = body_lines
                    .iter()
                    .any(|&k| RELEASE_TOKENS.iter().any(|t| lines[k].code.contains(t)));
                let escapes = ESCAPE_TYPES.iter().any(|t| sig.contains(t));
                let allowed =
                    body_lines.iter().any(|&k| suppressed(&lines[k].raw, "alloc-pairing"));
                if !releases && !escapes && !allowed {
                    findings.push(Finding {
                        file: file.to_string(),
                        line: no,
                        rule: "alloc-pairing",
                        message: "fn acquires ledger memory but neither releases it nor \
                                  returns the id (AllocId/ResidentBlock) — pair the alloc \
                                  or add `// lint: allow(alloc-pairing): <reason>`"
                            .to_string(),
                    });
                }
            }
        }
        i = j.max(i) + 1;
    }
}

/// `fn ` at a word boundary (skips `fn_ptr`-like identifiers).
fn find_fn(code: &str) -> Option<usize> {
    let mut from = 0;
    while let Some(rel) = code[from..].find("fn ") {
        let at = from + rel;
        let before_ok = at == 0
            || !code.as_bytes()[at - 1].is_ascii_alphanumeric() && code.as_bytes()[at - 1] != b'_';
        if before_ok {
            return Some(at);
        }
        from = at + 3;
    }
    None
}

/// Rules B and C: forbidden tokens in scoped files, suppressible on the
/// offending line or any preceding line's comment.
fn check_tokens(
    file: &str,
    lines: &[Line],
    tokens: &[&str],
    rule: &'static str,
    findings: &mut Vec<Finding>,
) {
    for (idx, l) in lines.iter().enumerate() {
        for t in tokens {
            if l.code.contains(t) {
                let allowed = lines[idx.saturating_sub(4)..=idx]
                    .iter()
                    .any(|p| suppressed(&p.raw, rule));
                if !allowed {
                    findings.push(Finding {
                        file: file.to_string(),
                        line: l.no,
                        rule,
                        message: format!(
                            "`{t}` is banned here (scoped {rule} rule) — use the pool / \
                             virtual clock, or add `// lint: allow({rule}): <reason>`"
                        ),
                    });
                }
            }
        }
    }
}
